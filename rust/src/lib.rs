//! # lfsr-prune
//!
//! Production-grade reproduction of *"Hardware-aware Pruning of DNNs using
//! LFSR-Generated Pseudo-Random Indices"* (Karimzadeh et al., 2019).
//!
//! Three-layer architecture (see DESIGN.md):
//! * **L3 (this crate)** — rust coordinator: LFSR primitives, masks, data,
//!   the training pipeline driving AOT-compiled JAX steps over PJRT, the
//!   65nm accelerator model, the experiment harness regenerating every
//!   table and figure of the paper, and the batched multi-threaded
//!   serving engine (`serve`) that re-derives non-zero positions from
//!   LFSR seeds at model load.
//! * **L2** — `python/compile/model.py`: JAX fwd/bwd, lowered once to HLO
//!   text artifacts (`make artifacts`).
//! * **L1** — `python/compile/kernels/`: Pallas masked-matmul and LFSR
//!   jump-index kernels, lowered inside the L2 HLO.
//!
//! Python never runs at request time: the `repro` binary is self-contained
//! once `artifacts/` exists.  Compiled models persist as `.lfsrpack`
//! artifacts (`store`) — two LFSR seeds per layer are the entire on-disk
//! index state — and many artifacts serve side by side through
//! `store::ModelRegistry` over one shared worker pool.

// CI gates on `cargo clippy -- -D warnings`.  These allows carve out the
// style lints that fight the repo's index-heavy numeric idiom (explicit
// row/column loops, wide hardware-parameter constructors); everything
// correctness-oriented still denies.
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::manual_div_ceil,
    clippy::type_complexity
)]

pub mod cli;
pub mod data;
pub mod experiments;
pub mod report;
pub mod hw;
pub mod runtime;
pub mod util;
pub mod lfsr;
pub mod mask;
pub mod obs;
pub mod pipeline;
pub mod rank;
pub mod serve;
pub mod sparse;
pub mod store;
