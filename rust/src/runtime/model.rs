//! Model-level API over the raw runtime: parameter init, the train/eval
//! step calls with the manifest's input ordering, and checkpointing.
//!
//! Input orders (must match python/compile/aot.py exactly):
//!   train: params..., masks..., x, y, lam, lr, a_l1, a_l2, hard_on
//!   eval : params..., masks..., x, y
//!   fwd  : params..., masks..., x

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use super::{ModelManifest, Runtime, Tensor, TensorData};
use crate::data::rng::Pcg32;
use crate::data::{Batch, Dataset, EvalBatches};

/// The five scalar inputs controlling the training phase (paper Eq. 4-5).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StepScalars {
    /// Regularization strength λ (0 in dense/retrain phases).
    pub lam: f32,
    /// SGD learning rate.
    pub lr: f32,
    /// L1 blend of the prune-target penalty.
    pub a_l1: f32,
    /// L2 blend of the prune-target penalty.
    pub a_l2: f32,
    /// 0 = soft phase (full forward), 1 = hard phase (masked forward +
    /// projection, i.e. prune + retrain).
    pub hard_on: f32,
}

impl StepScalars {
    pub fn dense(lr: f32) -> Self {
        StepScalars {
            lam: 0.0,
            lr,
            a_l1: 0.0,
            a_l2: 0.0,
            hard_on: 0.0,
        }
    }

    /// Regularization phase: λ with an L1/L2 switch (paper §2.2).
    pub fn regularize(lam: f32, lr: f32, l1: bool) -> Self {
        StepScalars {
            lam,
            lr,
            a_l1: if l1 { 1.0 } else { 0.0 },
            a_l2: if l1 { 0.0 } else { 1.0 },
            hard_on: 0.0,
        }
    }

    /// Retrain phase: pruned synapses frozen at zero (paper §2.3).
    pub fn retrain(lr: f32) -> Self {
        StepScalars {
            lam: 0.0,
            lr,
            a_l1: 0.0,
            a_l2: 0.0,
            hard_on: 1.0,
        }
    }
}

/// Aggregated evaluation result.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EvalMetrics {
    pub loss: f32,
    pub accuracy: f32,
    pub examples: usize,
}

impl EvalMetrics {
    /// Top-1 error in percent (the paper's reporting unit).
    pub fn error_pct(&self) -> f32 {
        (1.0 - self.accuracy) * 100.0
    }
}

/// One model bound to a runtime: the coordinator's main handle.
pub struct ModelRunner<'rt> {
    rt: &'rt Runtime,
    pub man: ModelManifest,
}

impl<'rt> ModelRunner<'rt> {
    pub fn new(rt: &'rt Runtime, model: &str) -> Result<Self> {
        Ok(ModelRunner {
            man: rt.model(model)?,
            rt,
        })
    }

    /// Glorot-uniform init for `*_w`, zeros for biases — matches the
    /// python init scheme (values differ; only the distribution matters,
    /// training happens entirely on this side).
    pub fn init_params(&self, seed: u64) -> Vec<Tensor> {
        let mut rng = Pcg32::new(seed);
        self.man
            .params
            .iter()
            .map(|p| {
                let n = p.len();
                if p.name.ends_with("_b") || p.shape.len() == 1 {
                    Tensor::zeros(p.shape.clone())
                } else {
                    let fan_in: usize = p.shape[..p.shape.len() - 1].iter().product();
                    let fan_out = p.shape[p.shape.len() - 1];
                    let lim = (6.0 / (fan_in + fan_out) as f32).sqrt();
                    let data: Vec<f32> =
                        (0..n).map(|_| (rng.next_f32() * 2.0 - 1.0) * lim).collect();
                    Tensor::f32(p.shape.clone(), data)
                }
            })
            .collect()
    }

    /// Dense (all-ones) masks for every maskable layer.
    pub fn dense_masks(&self) -> Vec<Tensor> {
        self.man
            .mask_shapes()
            .into_iter()
            .map(|s| {
                let n = s.iter().product();
                Tensor::f32(s, vec![1.0; n])
            })
            .collect()
    }

    fn artifact(&self, kind: &str) -> Result<&str> {
        self.man
            .artifacts
            .get(kind)
            .map(String::as_str)
            .ok_or_else(|| anyhow!("model {} has no {kind} artifact", self.man.name))
    }

    fn check_shapes(&self, params: &[Tensor], masks: &[Tensor], batch: &Batch) -> Result<()> {
        if params.len() != self.man.params.len() {
            bail!(
                "expected {} params, got {}",
                self.man.params.len(),
                params.len()
            );
        }
        for (t, spec) in params.iter().zip(&self.man.params) {
            if t.dims != spec.shape {
                bail!("param {}: dims {:?} != {:?}", spec.name, t.dims, spec.shape);
            }
        }
        let mshapes = self.man.mask_shapes();
        if masks.len() != mshapes.len() {
            bail!("expected {} masks, got {}", mshapes.len(), masks.len());
        }
        for (t, s) in masks.iter().zip(&mshapes) {
            if &t.dims != s {
                bail!("mask dims {:?} != {:?}", t.dims, s);
            }
        }
        if batch.size != self.man.batch {
            bail!("batch size {} != compiled {}", batch.size, self.man.batch);
        }
        Ok(())
    }

    /// One SGD step; returns (new_params, loss, batch accuracy).
    pub fn train_step(
        &self,
        params: &[Tensor],
        masks: &[Tensor],
        batch: &Batch,
        sc: StepScalars,
    ) -> Result<(Vec<Tensor>, f32, f32)> {
        self.check_shapes(params, masks, batch)?;
        let mut inputs: Vec<Tensor> = Vec::with_capacity(params.len() + masks.len() + 7);
        inputs.extend(params.iter().cloned());
        inputs.extend(masks.iter().cloned());
        inputs.push(Tensor::f32(self.man.batch_x_shape(), batch.x.clone()));
        inputs.push(Tensor::i32(vec![self.man.batch], batch.y.clone()));
        for v in [sc.lam, sc.lr, sc.a_l1, sc.a_l2, sc.hard_on] {
            inputs.push(Tensor::scalar_f32(v));
        }
        let mut outs = self.rt.execute(self.artifact("train")?, &inputs)?;
        if outs.len() != params.len() + 2 {
            bail!(
                "train step returned {} outputs, expected {}",
                outs.len(),
                params.len() + 2
            );
        }
        let acc = outs.pop().unwrap().scalar_value();
        let loss = outs.pop().unwrap().scalar_value();
        Ok((outs, loss, acc))
    }

    /// Evaluate over (up to `limit` examples of) a dataset.
    pub fn eval(
        &self,
        params: &[Tensor],
        masks: &[Tensor],
        data: &Dataset,
        limit: Option<usize>,
    ) -> Result<EvalMetrics> {
        let eval_file = self.artifact("eval")?.to_string();
        let mut total = 0usize;
        let mut loss_sum = 0f64;
        let mut acc_sum = 0f64;
        let limit = limit.unwrap_or(data.n);
        let mut head: Vec<Tensor> = Vec::with_capacity(params.len() + masks.len());
        head.extend(params.iter().cloned());
        head.extend(masks.iter().cloned());
        for (batch, real) in EvalBatches::new(data, self.man.batch) {
            if total >= limit {
                break;
            }
            let mut inputs = head.clone();
            inputs.push(Tensor::f32(self.man.batch_x_shape(), batch.x));
            inputs.push(Tensor::i32(vec![self.man.batch], batch.y));
            let outs = self.rt.execute(&eval_file, &inputs)?;
            // Padded tail examples bias the mean slightly; weight by the
            // full batch but count real examples — exact when B | n, and
            // the experiment datasets are sized that way.
            loss_sum += outs[0].scalar_value() as f64 * real as f64;
            acc_sum += outs[1].scalar_value() as f64 * real as f64;
            total += real;
        }
        Ok(EvalMetrics {
            loss: (loss_sum / total as f64) as f32,
            accuracy: (acc_sum / total as f64) as f32,
            examples: total,
        })
    }

    /// Run a whole training phase keeping parameters as XLA literals
    /// between steps — the §Perf hot-loop path.
    ///
    /// `train_step` converts every param Tensor→Literal on upload and
    /// Literal→Tensor on download, ~2 MB of memcpy per lenet300 step.
    /// Since step outputs are already literals and masks/scalars don't
    /// change within a phase, the loop below uploads params once, reuses
    /// mask/scalar literals, and only marshals x/y per step.  Returns the
    /// new params and the per-step losses.
    pub fn train_phase(
        &self,
        params: &[Tensor],
        masks: &[Tensor],
        batches: &mut dyn FnMut() -> Batch,
        steps: usize,
        sc: StepScalars,
        mut on_step: Option<&mut dyn FnMut(usize, f32)>,
    ) -> Result<(Vec<Tensor>, Vec<f32>)> {
        if steps == 0 {
            return Ok((params.to_vec(), Vec::new()));
        }
        let file = self.artifact("train")?.to_string();
        let np = params.len();
        let mut param_lits: Vec<xla::Literal> = params
            .iter()
            .map(Tensor::to_literal)
            .collect::<Result<Vec<_>>>()?;
        let mask_lits: Vec<xla::Literal> = masks
            .iter()
            .map(Tensor::to_literal)
            .collect::<Result<Vec<_>>>()?;
        let scalar_lits: Vec<xla::Literal> = [sc.lam, sc.lr, sc.a_l1, sc.a_l2, sc.hard_on]
            .iter()
            .map(|&v| xla::Literal::scalar(v))
            .collect();
        let mut losses = Vec::with_capacity(steps);
        for i in 0..steps {
            let b = batches();
            if b.size != self.man.batch {
                bail!("batch size {} != compiled {}", b.size, self.man.batch);
            }
            let x = Tensor::f32(self.man.batch_x_shape(), b.x).to_literal()?;
            let y = Tensor::i32(vec![self.man.batch], b.y).to_literal()?;
            let mut inputs: Vec<&xla::Literal> =
                Vec::with_capacity(np + mask_lits.len() + 7);
            inputs.extend(param_lits.iter());
            inputs.extend(mask_lits.iter());
            inputs.push(&x);
            inputs.push(&y);
            inputs.extend(scalar_lits.iter());
            // Self-managed buffer path (the shim's literal `execute`
            // leaks its temp buffers — see Runtime::execute_literals).
            let exe = self.rt.executable(&file)?;
            let client = exe.client();
            let bufs: Vec<xla::PjRtBuffer> = inputs
                .iter()
                .map(|l| {
                    client
                        .buffer_from_host_literal(None, l)
                        .map_err(|e| anyhow!("upload: {e:?}"))
                })
                .collect::<Result<Vec<_>>>()?;
            let refs: Vec<&xla::PjRtBuffer> = bufs.iter().collect();
            let result = exe
                .execute_b::<&xla::PjRtBuffer>(&refs)
                .map_err(|e| anyhow!("executing {file}: {e:?}"))?;
            let out = result[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow!("downloading {file}: {e:?}"))?;
            let mut outs = out.to_tuple().map_err(|e| anyhow!("untuple: {e:?}"))?;
            if outs.len() != np + 2 {
                bail!("train step returned {} outputs", outs.len());
            }
            let acc = outs.pop().unwrap();
            let loss_lit = outs.pop().unwrap();
            let _ = acc;
            let loss = loss_lit.get_first_element::<f32>()?;
            losses.push(loss);
            if let Some(cb) = on_step.as_deref_mut() {
                cb(i, loss);
            }
            param_lits = outs; // stay in literal form — no host round-trip
        }
        let new_params = param_lits
            .iter()
            .map(Tensor::from_literal)
            .collect::<Result<Vec<_>>>()?;
        Ok((new_params, losses))
    }

    /// Forward pass: logits for one batch.
    pub fn forward(&self, params: &[Tensor], masks: &[Tensor], x: Vec<f32>) -> Result<Tensor> {
        let mut inputs: Vec<Tensor> = Vec::with_capacity(params.len() + masks.len() + 1);
        inputs.extend(params.iter().cloned());
        inputs.extend(masks.iter().cloned());
        inputs.push(Tensor::f32(self.man.batch_x_shape(), x));
        let outs = self.rt.execute(self.artifact("fwd")?, &inputs)?;
        Ok(outs.into_iter().next().unwrap())
    }

    /// Forward a *partial* batch of `real` examples, zero-padding up to
    /// the compiled batch size; returns logits trimmed to `[real, K]`.
    /// The serving front-end (`serve::Batcher`) pads exactly this way, so
    /// the artifact-backed and native paths agree on partial batches.
    pub fn forward_padded(
        &self,
        params: &[Tensor],
        masks: &[Tensor],
        x: &[f32],
        real: usize,
    ) -> Result<Tensor> {
        let shape = self.man.batch_x_shape();
        let example_len: usize = shape[1..].iter().product();
        if real == 0 || real > self.man.batch {
            bail!("real {} outside 1..={}", real, self.man.batch);
        }
        if x.len() != real * example_len {
            bail!("input length {} != {real} x {example_len}", x.len());
        }
        let mut full = vec![0.0f32; self.man.batch * example_len];
        full[..x.len()].copy_from_slice(x);
        let logits = self.forward(params, masks, full)?;
        if real == self.man.batch {
            return Ok(logits);
        }
        let k: usize = logits.dims[1..].iter().product();
        let data = logits.as_f32()[..real * k].to_vec();
        let mut dims = logits.dims.clone();
        dims[0] = real;
        Ok(Tensor::f32(dims, data))
    }

    /// Indices of maskable params within the params vec.
    pub fn maskable_indices(&self) -> Vec<usize> {
        self.man
            .maskable
            .iter()
            .map(|m| {
                self.man
                    .params
                    .iter()
                    .position(|p| &p.name == m)
                    .expect("validated by manifest load")
            })
            .collect()
    }
}

// ---------------------------------------------------------------------------
// Checkpointing (simple length-prefixed binary; no serde offline)
// ---------------------------------------------------------------------------

const CKPT_MAGIC: &[u8; 8] = b"LFSRPRN1";

/// Save params to a checkpoint file.
pub fn save_checkpoint(path: &Path, names: &[String], params: &[Tensor]) -> Result<()> {
    assert_eq!(names.len(), params.len());
    let mut f = std::fs::File::create(path).with_context(|| format!("creating {path:?}"))?;
    f.write_all(CKPT_MAGIC)?;
    f.write_all(&(params.len() as u32).to_le_bytes())?;
    for (name, t) in names.iter().zip(params) {
        f.write_all(&(name.len() as u32).to_le_bytes())?;
        f.write_all(name.as_bytes())?;
        f.write_all(&(t.dims.len() as u32).to_le_bytes())?;
        for &d in &t.dims {
            f.write_all(&(d as u64).to_le_bytes())?;
        }
        match &t.data {
            TensorData::F32(v) => {
                f.write_all(&[0u8])?;
                for x in v {
                    f.write_all(&x.to_le_bytes())?;
                }
            }
            TensorData::I32(v) => {
                f.write_all(&[1u8])?;
                for x in v {
                    f.write_all(&x.to_le_bytes())?;
                }
            }
        }
    }
    Ok(())
}

/// Load a checkpoint; returns (names, tensors).
pub fn load_checkpoint(path: &Path) -> Result<(Vec<String>, Vec<Tensor>)> {
    let mut f = std::fs::File::open(path).with_context(|| format!("opening {path:?}"))?;
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic)?;
    if &magic != CKPT_MAGIC {
        bail!("bad checkpoint magic");
    }
    let mut u32b = [0u8; 4];
    f.read_exact(&mut u32b)?;
    let count = u32::from_le_bytes(u32b) as usize;
    let mut names = Vec::with_capacity(count);
    let mut tensors = Vec::with_capacity(count);
    for _ in 0..count {
        f.read_exact(&mut u32b)?;
        let nlen = u32::from_le_bytes(u32b) as usize;
        let mut nbuf = vec![0u8; nlen];
        f.read_exact(&mut nbuf)?;
        names.push(String::from_utf8(nbuf)?);
        f.read_exact(&mut u32b)?;
        let ndims = u32::from_le_bytes(u32b) as usize;
        let mut dims = Vec::with_capacity(ndims);
        let mut u64b = [0u8; 8];
        for _ in 0..ndims {
            f.read_exact(&mut u64b)?;
            dims.push(u64::from_le_bytes(u64b) as usize);
        }
        let n: usize = dims.iter().product();
        let mut tag = [0u8; 1];
        f.read_exact(&mut tag)?;
        let mut buf = vec![0u8; n * 4];
        f.read_exact(&mut buf)?;
        let t = match tag[0] {
            0 => Tensor::f32(
                dims,
                buf.chunks_exact(4)
                    .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect(),
            ),
            1 => Tensor::i32(
                dims,
                buf.chunks_exact(4)
                    .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect(),
            ),
            t => bail!("bad dtype tag {t}"),
        };
        tensors.push(t);
    }
    Ok((names, tensors))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars_presets() {
        let d = StepScalars::dense(0.1);
        assert_eq!(d.hard_on, 0.0);
        assert_eq!(d.lam, 0.0);
        let r = StepScalars::regularize(2.0, 0.05, false);
        assert_eq!((r.a_l1, r.a_l2), (0.0, 1.0));
        let l1 = StepScalars::regularize(2.0, 0.05, true);
        assert_eq!((l1.a_l1, l1.a_l2), (1.0, 0.0));
        let rt = StepScalars::retrain(0.02);
        assert_eq!(rt.hard_on, 1.0);
    }

    #[test]
    fn checkpoint_roundtrip() {
        let dir = std::env::temp_dir().join("lfsr_prune_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("test.ckpt");
        let names = vec!["a_w".to_string(), "a_b".to_string(), "labels".to_string()];
        let tensors = vec![
            Tensor::f32(vec![2, 3], vec![1., -2., 3., 4., 5.5, -6.]),
            Tensor::f32(vec![3], vec![0.1, 0.2, 0.3]),
            Tensor::i32(vec![4], vec![1, 2, 3, 4]),
        ];
        save_checkpoint(&path, &names, &tensors).unwrap();
        let (n2, t2) = load_checkpoint(&path).unwrap();
        assert_eq!(n2, names);
        assert_eq!(t2, tensors);
    }

    #[test]
    fn eval_metrics_error_pct() {
        let m = EvalMetrics {
            loss: 1.0,
            accuracy: 0.951,
            examples: 1000,
        };
        assert!((m.error_pct() - 4.9).abs() < 1e-4);
    }
}
