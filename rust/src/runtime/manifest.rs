//! Typed view of `artifacts/manifest.json` (written by
//! `python/compile/aot.py`): everything the coordinator needs to marshal
//! literals for each AOT-compiled step function.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::util::json::{parse, Json};

/// One named parameter (order in the vec = positional input order).
#[derive(Debug, Clone, PartialEq)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

impl ParamSpec {
    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Everything known about one model's artifacts.
#[derive(Debug, Clone)]
pub struct ModelManifest {
    pub name: String,
    pub batch: usize,
    pub input_shape: Vec<usize>,
    pub num_classes: usize,
    pub use_pallas: bool,
    pub params: Vec<ParamSpec>,
    /// Names of maskable (FC weight) params, in mask input order.
    pub maskable: Vec<String>,
    /// Scalar input order for the train step.
    pub scalar_inputs: Vec<String>,
    /// kind ("train"/"eval"/"fwd") -> artifact file name.
    pub artifacts: BTreeMap<String, String>,
    pub param_count: usize,
}

impl ModelManifest {
    /// Shapes of the mask inputs (same as the maskable params' shapes).
    pub fn mask_shapes(&self) -> Vec<Vec<usize>> {
        self.maskable
            .iter()
            .map(|m| {
                self.params
                    .iter()
                    .find(|p| &p.name == m)
                    .unwrap_or_else(|| panic!("maskable {m} not in params"))
                    .shape
                    .clone()
            })
            .collect()
    }

    pub fn batch_x_shape(&self) -> Vec<usize> {
        let mut s = vec![self.batch];
        s.extend(&self.input_shape);
        s
    }
}

/// Kernel demo artifact entries (runtime smoke tests / cross-checks).
#[derive(Debug, Clone)]
pub struct KernelManifest {
    pub name: String,
    pub file: String,
    pub fields: BTreeMap<String, f64>,
}

/// The full manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub models: BTreeMap<String, ModelManifest>,
    pub kernels: BTreeMap<String, KernelManifest>,
}

fn usize_arr(j: &Json) -> Result<Vec<usize>> {
    j.as_arr()
        .ok_or_else(|| anyhow!("expected array"))?
        .iter()
        .map(|v| v.as_usize().ok_or_else(|| anyhow!("expected number")))
        .collect()
}

fn str_arr(j: &Json) -> Result<Vec<String>> {
    j.as_arr()
        .ok_or_else(|| anyhow!("expected array"))?
        .iter()
        .map(|v| {
            v.as_str()
                .map(str::to_string)
                .ok_or_else(|| anyhow!("expected string"))
        })
        .collect()
}

impl Manifest {
    /// Load and validate `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let j = parse(&text).map_err(|e| anyhow!("{e}"))?;
        Self::from_json(&j)
    }

    pub fn from_json(j: &Json) -> Result<Manifest> {
        let mut models = BTreeMap::new();
        for (name, m) in j
            .get("models")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow!("manifest missing models"))?
        {
            let params = m
                .get("params")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("{name}: missing params"))?
                .iter()
                .map(|p| {
                    Ok(ParamSpec {
                        name: p
                            .get("name")
                            .and_then(Json::as_str)
                            .ok_or_else(|| anyhow!("param missing name"))?
                            .to_string(),
                        shape: usize_arr(p.get("shape").ok_or_else(|| anyhow!("no shape"))?)?,
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            let mm = ModelManifest {
                name: name.clone(),
                batch: m
                    .get("batch")
                    .and_then(Json::as_usize)
                    .ok_or_else(|| anyhow!("{name}: missing batch"))?,
                input_shape: usize_arr(
                    m.get("input_shape").ok_or_else(|| anyhow!("no input_shape"))?,
                )?,
                num_classes: m
                    .get("num_classes")
                    .and_then(Json::as_usize)
                    .ok_or_else(|| anyhow!("{name}: missing num_classes"))?,
                use_pallas: m.get("use_pallas").and_then(Json::as_bool).unwrap_or(false),
                maskable: str_arr(m.get("maskable").ok_or_else(|| anyhow!("no maskable"))?)?,
                scalar_inputs: str_arr(
                    m.get("scalar_inputs").ok_or_else(|| anyhow!("no scalar_inputs"))?,
                )?,
                artifacts: m
                    .get("artifacts")
                    .and_then(Json::as_obj)
                    .ok_or_else(|| anyhow!("{name}: missing artifacts"))?
                    .iter()
                    .map(|(k, v)| {
                        Ok((
                            k.clone(),
                            v.as_str()
                                .ok_or_else(|| anyhow!("artifact not a string"))?
                                .to_string(),
                        ))
                    })
                    .collect::<Result<BTreeMap<_, _>>>()?,
                param_count: m
                    .get("param_count")
                    .and_then(Json::as_usize)
                    .unwrap_or(0),
                params,
            };
            // Validation: every maskable name must be a param.
            for mk in &mm.maskable {
                if !mm.params.iter().any(|p| &p.name == mk) {
                    return Err(anyhow!("{name}: maskable {mk} not among params"));
                }
            }
            models.insert(name.clone(), mm);
        }
        let mut kernels = BTreeMap::new();
        if let Some(ks) = j.get("kernels").and_then(Json::as_obj) {
            for (name, k) in ks {
                let mut fields = BTreeMap::new();
                if let Some(obj) = k.as_obj() {
                    for (fk, fv) in obj {
                        if let Some(n) = fv.as_f64() {
                            fields.insert(fk.clone(), n);
                        }
                    }
                }
                kernels.insert(
                    name.clone(),
                    KernelManifest {
                        name: name.clone(),
                        file: k
                            .get("file")
                            .and_then(Json::as_str)
                            .ok_or_else(|| anyhow!("kernel {name}: missing file"))?
                            .to_string(),
                        fields,
                    },
                );
            }
        }
        Ok(Manifest { models, kernels })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1,
      "models": {
        "lenet300": {
          "batch": 64,
          "input_shape": [784],
          "num_classes": 10,
          "use_pallas": true,
          "params": [
            {"name": "fc1_w", "shape": [784, 300]},
            {"name": "fc1_b", "shape": [300]}
          ],
          "maskable": ["fc1_w"],
          "scalar_inputs": ["lam", "lr", "a_l1", "a_l2", "hard_on"],
          "artifacts": {"train": "lenet300_train.hlo.txt"},
          "param_count": 235500
        }
      },
      "kernels": {
        "lfsr_idx": {"file": "lfsr_idx.hlo.txt", "n": 16, "domain": 1024}
      }
    }"#;

    #[test]
    fn parses_sample() {
        let j = parse(SAMPLE).unwrap();
        let m = Manifest::from_json(&j).unwrap();
        let l = &m.models["lenet300"];
        assert_eq!(l.batch, 64);
        assert_eq!(l.params[0].shape, vec![784, 300]);
        assert_eq!(l.mask_shapes(), vec![vec![784, 300]]);
        assert_eq!(l.batch_x_shape(), vec![64, 784]);
        assert_eq!(m.kernels["lfsr_idx"].fields["domain"], 1024.0);
    }

    #[test]
    fn rejects_bad_maskable() {
        let bad = SAMPLE.replace("\"maskable\": [\"fc1_w\"]", "\"maskable\": [\"nope\"]");
        let j = parse(&bad).unwrap();
        assert!(Manifest::from_json(&j).is_err());
    }

    #[test]
    fn loads_real_manifest_when_present() {
        let dir = std::path::Path::new(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"));
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(dir).unwrap();
            assert!(m.models.contains_key("lenet300"));
            let l = &m.models["lenet300"];
            assert_eq!(l.param_count, 266_610);
            assert_eq!(l.maskable.len(), 3);
        }
    }
}
