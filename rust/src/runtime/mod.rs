//! PJRT runtime: loads the AOT HLO-text artifacts and executes them.
//!
//! `Runtime` owns one PJRT CPU client plus a compiled-executable cache
//! keyed by artifact file.  Compilation happens once per process per
//! artifact; the training hot loop only calls `execute`.
//!
//! Thread model: PJRT wrapper types are not `Send`, so a `Runtime` is
//! deliberately single-threaded; the trial coordinator
//! (`pipeline::trials`) gives each worker thread its own `Runtime`.

pub mod manifest;
pub mod model;
pub mod tensor;

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

use anyhow::{anyhow, Context, Result};

pub use manifest::{Manifest, ModelManifest, ParamSpec};
pub use model::{EvalMetrics, ModelRunner, StepScalars};
pub use tensor::{Tensor, TensorData};

/// PJRT client + artifact registry + executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    pub manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
}

impl Runtime {
    /// Open the artifact directory (default: `<repo>/artifacts`).
    pub fn new(artifacts_dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = artifacts_dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        Ok(Runtime {
            client,
            dir,
            manifest,
            cache: RefCell::new(HashMap::new()),
        })
    }

    /// Resolve the repo-default artifacts directory.
    pub fn default_dir() -> PathBuf {
        // Prefer CARGO_MANIFEST_DIR (tests/benches), fall back to cwd.
        std::env::var("LFSR_PRUNE_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| {
                let mani = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
                if mani.exists() {
                    mani
                } else {
                    PathBuf::from("artifacts")
                }
            })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch cached) an artifact by file name.
    pub fn executable(&self, file: &str) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.cache.borrow().get(file) {
            return Ok(e.clone());
        }
        let path = self.dir.join(file);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("loading {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {file}: {e:?}"))?;
        let exe = Rc::new(exe);
        self.cache.borrow_mut().insert(file.to_string(), exe.clone());
        Ok(exe)
    }

    /// Execute an artifact on host tensors; returns the decomposed output
    /// tuple (artifacts are always lowered with `return_tuple=True`).
    pub fn execute(&self, file: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let lits = inputs
            .iter()
            .map(Tensor::to_literal)
            .collect::<Result<Vec<_>>>()?;
        let outs = self.execute_literals(file, &lits)?;
        outs.iter().map(Tensor::from_literal).collect()
    }

    /// Literal-level execute (used by the hot loop to avoid re-marshalling
    /// inputs that don't change between steps, e.g. masks).
    ///
    /// Inputs are uploaded as self-managed `PjRtBuffer`s and run through
    /// `execute_b`: the shim's literal-input `execute` path leaks its
    /// temporary device buffers (~22 KB/call measured — see EXPERIMENTS.md
    /// §Perf "leak"), while buffers we own are freed by their rust `Drop`.
    pub fn execute_literals(&self, file: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let exe = self.executable(file)?;
        let client = exe.client();
        let bufs: Vec<xla::PjRtBuffer> = inputs
            .iter()
            .map(|l| {
                client
                    .buffer_from_host_literal(None, l)
                    .map_err(|e| anyhow!("uploading input for {file}: {e:?}"))
            })
            .collect::<Result<Vec<_>>>()?;
        let refs: Vec<&xla::PjRtBuffer> = bufs.iter().collect();
        let result = exe
            .execute_b::<&xla::PjRtBuffer>(&refs)
            .map_err(|e| anyhow!("executing {file}: {e:?}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("downloading result of {file}: {e:?}"))?;
        lit.to_tuple().map_err(|e| anyhow!("untupling {file}: {e:?}"))
    }

    /// Model manifest lookup with a helpful error.
    pub fn model(&self, name: &str) -> Result<ModelManifest> {
        self.manifest
            .models
            .get(name)
            .cloned()
            .with_context(|| format!("model {name} not in manifest (have: {:?})",
                self.manifest.models.keys().collect::<Vec<_>>()))
    }

    /// Number of compiled executables currently cached.
    pub fn cached_executables(&self) -> usize {
        self.cache.borrow().len()
    }
}
