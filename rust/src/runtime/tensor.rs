//! Host-side tensor type: the coordinator's view of model parameters,
//! masks and batches, marshalled to/from PJRT literals at the call edge.

use anyhow::{bail, Result};

/// Row-major host tensor (f32 or i32 — the only dtypes the artifacts use).
#[derive(Debug, Clone, PartialEq)]
pub enum TensorData {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub dims: Vec<usize>,
    pub data: TensorData,
}

impl Tensor {
    pub fn f32(dims: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(dims.iter().product::<usize>(), data.len());
        Tensor {
            dims,
            data: TensorData::F32(data),
        }
    }

    pub fn i32(dims: Vec<usize>, data: Vec<i32>) -> Self {
        assert_eq!(dims.iter().product::<usize>(), data.len());
        Tensor {
            dims,
            data: TensorData::I32(data),
        }
    }

    pub fn zeros(dims: Vec<usize>) -> Self {
        let n = dims.iter().product();
        Tensor::f32(dims, vec![0.0; n])
    }

    pub fn scalar_f32(v: f32) -> Self {
        Tensor::f32(vec![], vec![v])
    }

    pub fn len(&self) -> usize {
        self.dims.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> &[f32] {
        match &self.data {
            TensorData::F32(v) => v,
            _ => panic!("tensor is not f32"),
        }
    }

    pub fn as_f32_mut(&mut self) -> &mut [f32] {
        match &mut self.data {
            TensorData::F32(v) => v,
            _ => panic!("tensor is not f32"),
        }
    }

    pub fn as_i32(&self) -> &[i32] {
        match &self.data {
            TensorData::I32(v) => v,
            _ => panic!("tensor is not i32"),
        }
    }

    /// Upload as an XLA literal (copies; upload cost measured in
    /// benches/pjrt_step.rs).
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.dims.iter().map(|&d| d as i64).collect();
        let lit = match &self.data {
            TensorData::F32(v) => {
                if dims.is_empty() {
                    xla::Literal::scalar(v[0])
                } else {
                    xla::Literal::vec1(v).reshape(&dims)?
                }
            }
            TensorData::I32(v) => {
                if dims.is_empty() {
                    xla::Literal::scalar(v[0])
                } else {
                    xla::Literal::vec1(v).reshape(&dims)?
                }
            }
        };
        Ok(lit)
    }

    /// Download from an XLA literal.
    pub fn from_literal(lit: &xla::Literal) -> Result<Tensor> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            xla::ElementType::F32 => Ok(Tensor::f32(dims, lit.to_vec::<f32>()?)),
            xla::ElementType::S32 => Ok(Tensor::i32(dims, lit.to_vec::<i32>()?)),
            other => bail!("unsupported element type {other:?}"),
        }
    }

    /// Scalar extraction for loss/acc outputs.
    pub fn scalar_value(&self) -> f32 {
        assert_eq!(self.len(), 1, "not a scalar: dims {:?}", self.dims);
        match &self.data {
            TensorData::F32(v) => v[0],
            TensorData::I32(v) => v[0] as f32,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_access() {
        let t = Tensor::f32(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.len(), 6);
        assert_eq!(t.as_f32()[4], 5.0);
        let s = Tensor::scalar_f32(7.5);
        assert_eq!(s.scalar_value(), 7.5);
    }

    #[test]
    fn literal_roundtrip_f32() {
        let t = Tensor::f32(vec![4, 2], (0..8).map(|v| v as f32).collect());
        let lit = t.to_literal().unwrap();
        let back = Tensor::from_literal(&lit).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn literal_roundtrip_i32() {
        let t = Tensor::i32(vec![5], vec![1, -2, 3, -4, 5]);
        let lit = t.to_literal().unwrap();
        let back = Tensor::from_literal(&lit).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn literal_roundtrip_scalar() {
        let t = Tensor::scalar_f32(0.25);
        let lit = t.to_literal().unwrap();
        let back = Tensor::from_literal(&lit).unwrap();
        assert_eq!(back.scalar_value(), 0.25);
        assert!(back.dims.is_empty());
    }

    #[test]
    #[should_panic]
    fn dims_data_mismatch_panics() {
        Tensor::f32(vec![2, 2], vec![1.0]);
    }
}
