//! Synthetic datasets standing in for MNIST / CIFAR-10 / down-sampled
//! ImageNet (DESIGN.md §Substitutions).
//!
//! Each class c gets a smooth random prototype image P_c (coarse random
//! grid, bilinearly upsampled — low-frequency structure like natural
//! images); a sample is `contrast · P_c + noise · N(0,1)`, clipped to
//! [0, 1].  Pruning-vs-accuracy behaviour depends on over-parameterization
//! relative to task difficulty, which the `noise`/`contrast` knobs tune:
//! the defaults make dense LeNets reach high accuracy while 90%+ sparsity
//! visibly degrades — the regime of the paper's Figures 3-4.

use super::rng::Pcg32;
use super::Dataset;

/// Generation parameters for one synthetic dataset.
#[derive(Debug, Clone)]
pub struct SynthSpec {
    pub height: usize,
    pub width: usize,
    pub channels: usize,
    pub classes: usize,
    /// Coarse prototype grid edge (lower = smoother images).
    pub proto_grid: usize,
    /// Prototype contrast (signal amplitude).
    pub contrast: f32,
    /// Additive Gaussian noise sigma (task difficulty).
    pub noise: f32,
    pub seed: u64,
}

impl SynthSpec {
    /// MNIST stand-in: 28×28×1, 10 classes.
    pub fn mnist_like(seed: u64) -> Self {
        SynthSpec {
            height: 28,
            width: 28,
            channels: 1,
            classes: 10,
            proto_grid: 7,
            contrast: 1.0,
            noise: 0.25,
            seed,
        }
    }

    /// CIFAR-10 stand-in: 32×32×3, 10 classes (harder: more noise).
    pub fn cifar_like(seed: u64) -> Self {
        SynthSpec {
            height: 32,
            width: 32,
            channels: 3,
            classes: 10,
            proto_grid: 8,
            contrast: 0.9,
            noise: 0.35,
            seed,
        }
    }

    /// Down-sampled-ImageNet stand-in: 64×64×3, `classes` classes.  With
    /// 1000 classes and this noise the dense top-1 error lands in the
    /// paper's ~50% ballpark for the width-scaled VGG.
    pub fn imagenet64_like(classes: usize, seed: u64) -> Self {
        SynthSpec {
            height: 64,
            width: 64,
            channels: 3,
            classes,
            proto_grid: 8,
            contrast: 0.7,
            noise: 0.45,
            seed,
        }
    }

    pub fn example_len(&self) -> usize {
        self.height * self.width * self.channels
    }

    pub fn shape(&self) -> Vec<usize> {
        if self.channels == 1 && self.height * self.width == self.example_len() {
            vec![self.height, self.width, self.channels]
        } else {
            vec![self.height, self.width, self.channels]
        }
    }
}

/// Smooth prototype: coarse grid of N(0,1) upsampled bilinearly to H×W.
fn prototype(spec: &SynthSpec, rng: &mut Pcg32) -> Vec<f32> {
    let g = spec.proto_grid;
    let (h, w, ch) = (spec.height, spec.width, spec.channels);
    let mut coarse = vec![0.0f32; g * g * ch];
    for v in coarse.iter_mut() {
        *v = rng.next_normal();
    }
    let mut out = vec![0.0f32; h * w * ch];
    for y in 0..h {
        for x in 0..w {
            // Map pixel centre into coarse-grid coordinates.
            let fy = (y as f32 + 0.5) / h as f32 * (g - 1) as f32;
            let fx = (x as f32 + 0.5) / w as f32 * (g - 1) as f32;
            let (y0, x0) = (fy as usize, fx as usize);
            let (y1, x1) = ((y0 + 1).min(g - 1), (x0 + 1).min(g - 1));
            let (dy, dx) = (fy - y0 as f32, fx - x0 as f32);
            for c in 0..ch {
                let p00 = coarse[(y0 * g + x0) * ch + c];
                let p01 = coarse[(y0 * g + x1) * ch + c];
                let p10 = coarse[(y1 * g + x0) * ch + c];
                let p11 = coarse[(y1 * g + x1) * ch + c];
                let top = p00 * (1.0 - dx) + p01 * dx;
                let bot = p10 * (1.0 - dx) + p11 * dx;
                out[(y * w + x) * ch + c] = top * (1.0 - dy) + bot * dy;
            }
        }
    }
    out
}

/// Generate `n` samples (balanced classes, shuffled label order).
pub fn generate(spec: &SynthSpec, n: usize) -> Dataset {
    let mut rng = Pcg32::new(spec.seed);
    let protos: Vec<Vec<f32>> = (0..spec.classes).map(|_| prototype(spec, &mut rng)).collect();
    let len = spec.example_len();
    let mut x = vec![0.0f32; n * len];
    let mut y = vec![0i32; n];
    for i in 0..n {
        let c = rng.next_below(spec.classes as u32) as usize;
        y[i] = c as i32;
        let p = &protos[c];
        let dst = &mut x[i * len..(i + 1) * len];
        for (d, &pv) in dst.iter_mut().zip(p.iter()) {
            let v = 0.5 + 0.5 * spec.contrast * pv + spec.noise * rng.next_normal();
            *d = v.clamp(0.0, 1.0);
        }
    }
    Dataset {
        x,
        y,
        n,
        example_shape: spec.shape(),
        classes: spec.classes,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_determinism() {
        let spec = SynthSpec::mnist_like(1);
        let a = generate(&spec, 50);
        assert_eq!(a.x.len(), 50 * 28 * 28);
        assert_eq!(a.y.len(), 50);
        let b = generate(&spec, 50);
        assert_eq!(a.x, b.x);
        assert_eq!(a.y, b.y);
    }

    #[test]
    fn values_in_unit_range_labels_valid() {
        let spec = SynthSpec::cifar_like(3);
        let d = generate(&spec, 64);
        assert!(d.x.iter().all(|&v| (0.0..=1.0).contains(&v)));
        assert!(d.y.iter().all(|&c| (0..10).contains(&c)));
    }

    #[test]
    fn classes_are_separable() {
        // Nearest-prototype classification on clean prototypes must beat
        // chance by a wide margin — the datasets must be *learnable*.
        let spec = SynthSpec::mnist_like(5);
        let d = generate(&spec, 400);
        let mut protos = vec![vec![0.0f64; 784]; 10];
        let mut counts = vec![0usize; 10];
        // Estimate prototypes from the first half.
        for i in 0..200 {
            let c = d.y[i] as usize;
            counts[c] += 1;
            for j in 0..784 {
                protos[c][j] += d.x[i * 784 + j] as f64;
            }
        }
        for c in 0..10 {
            if counts[c] > 0 {
                for v in protos[c].iter_mut() {
                    *v /= counts[c] as f64;
                }
            }
        }
        // Classify the second half by nearest prototype.
        let mut correct = 0;
        for i in 200..400 {
            let xs = &d.x[i * 784..(i + 1) * 784];
            let best = (0..10)
                .min_by(|&a, &b| {
                    let da: f64 = xs.iter().zip(&protos[a]).map(|(&x, &p)| (x as f64 - p).powi(2)).sum();
                    let db: f64 = xs.iter().zip(&protos[b]).map(|(&x, &p)| (x as f64 - p).powi(2)).sum();
                    da.partial_cmp(&db).unwrap()
                })
                .unwrap();
            if best as i32 == d.y[i] {
                correct += 1;
            }
        }
        let acc = correct as f64 / 200.0;
        assert!(acc > 0.8, "synthetic task not separable: acc={acc}");
    }

    #[test]
    fn imagenet64_spec_dims() {
        let spec = SynthSpec::imagenet64_like(100, 1);
        let d = generate(&spec, 4);
        assert_eq!(d.example_shape, vec![64, 64, 3]);
        assert_eq!(d.x.len(), 4 * 64 * 64 * 3);
        assert_eq!(d.classes, 100);
    }
}
