//! Dataset substrate: in-memory datasets + deterministic batch iteration.
//!
//! The coordinator owns the data path end-to-end (generation, shuffling,
//! batching); the AOT-compiled step functions only ever see fixed-shape
//! `[B, ...]` f32 batches and `[B]` i32 labels.

pub mod rng;
pub mod synth;

pub use synth::{generate, SynthSpec};

/// An in-memory dataset: row-major examples + integer labels.
#[derive(Debug, Clone)]
pub struct Dataset {
    /// n × prod(example_shape), row-major.
    pub x: Vec<f32>,
    /// n labels in [0, classes).
    pub y: Vec<i32>,
    pub n: usize,
    pub example_shape: Vec<usize>,
    pub classes: usize,
}

impl Dataset {
    pub fn example_len(&self) -> usize {
        self.example_shape.iter().product()
    }

    /// Split off the last `k` examples as a held-out set.
    pub fn split_tail(&self, k: usize) -> (Dataset, Dataset) {
        assert!(k < self.n);
        let len = self.example_len();
        let head = Dataset {
            x: self.x[..(self.n - k) * len].to_vec(),
            y: self.y[..self.n - k].to_vec(),
            n: self.n - k,
            example_shape: self.example_shape.clone(),
            classes: self.classes,
        };
        let tail = Dataset {
            x: self.x[(self.n - k) * len..].to_vec(),
            y: self.y[self.n - k..].to_vec(),
            n: k,
            example_shape: self.example_shape.clone(),
            classes: self.classes,
        };
        (head, tail)
    }
}

/// One fixed-size batch view, already materialized for literal upload.
#[derive(Debug, Clone)]
pub struct Batch {
    pub x: Vec<f32>,
    pub y: Vec<i32>,
    pub size: usize,
}

/// Deterministic shuffling batcher: reshuffles each epoch with PCG32,
/// wraps across epochs, always yields exactly `batch` examples.
#[derive(Debug)]
pub struct Batcher<'a> {
    data: &'a Dataset,
    batch: usize,
    order: Vec<u32>,
    cursor: usize,
    rng: rng::Pcg32,
    pub epochs_completed: usize,
}

impl<'a> Batcher<'a> {
    pub fn new(data: &'a Dataset, batch: usize, seed: u64) -> Self {
        assert!(batch <= data.n, "batch {} > dataset {}", batch, data.n);
        let mut b = Batcher {
            data,
            batch,
            order: (0..data.n as u32).collect(),
            cursor: 0,
            rng: rng::Pcg32::new(seed),
            epochs_completed: 0,
        };
        b.shuffle();
        b
    }

    fn shuffle(&mut self) {
        let n = self.order.len();
        for i in (1..n).rev() {
            let j = self.rng.next_below((i + 1) as u32) as usize;
            self.order.swap(i, j);
        }
    }

    /// Next shuffled batch (reshuffles on epoch boundary; the final
    /// partial window of an epoch is completed from the next epoch's
    /// head so batch shape is always exact).
    pub fn next_batch(&mut self) -> Batch {
        let len = self.data.example_len();
        let mut x = Vec::with_capacity(self.batch * len);
        let mut y = Vec::with_capacity(self.batch);
        for _ in 0..self.batch {
            if self.cursor == self.order.len() {
                self.cursor = 0;
                self.epochs_completed += 1;
                self.shuffle();
            }
            let i = self.order[self.cursor] as usize;
            self.cursor += 1;
            x.extend_from_slice(&self.data.x[i * len..(i + 1) * len]);
            y.push(self.data.y[i]);
        }
        Batch {
            x,
            y,
            size: self.batch,
        }
    }
}

/// Sequential (unshuffled) batches for evaluation; the final short batch
/// is padded by repeating the last example, with the true count returned
/// so accuracy can be weighted correctly.
pub struct EvalBatches<'a> {
    data: &'a Dataset,
    batch: usize,
    cursor: usize,
}

impl<'a> EvalBatches<'a> {
    pub fn new(data: &'a Dataset, batch: usize) -> Self {
        EvalBatches {
            data,
            batch,
            cursor: 0,
        }
    }
}

impl<'a> Iterator for EvalBatches<'a> {
    /// (batch, number of real examples in it)
    type Item = (Batch, usize);

    fn next(&mut self) -> Option<(Batch, usize)> {
        if self.cursor >= self.data.n {
            return None;
        }
        let len = self.data.example_len();
        let real = (self.data.n - self.cursor).min(self.batch);
        let mut x = Vec::with_capacity(self.batch * len);
        let mut y = Vec::with_capacity(self.batch);
        for k in 0..self.batch {
            let i = (self.cursor + k).min(self.data.n - 1);
            x.extend_from_slice(&self.data.x[i * len..(i + 1) * len]);
            y.push(self.data.y[i]);
        }
        self.cursor += real;
        Some((
            Batch {
                x,
                y,
                size: self.batch,
            },
            real,
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Dataset {
        Dataset {
            x: (0..20).map(|v| v as f32).collect(),
            y: (0..10).map(|v| v % 3).collect(),
            n: 10,
            example_shape: vec![2],
            classes: 3,
        }
    }

    #[test]
    fn batcher_exact_size_and_epoch_coverage() {
        let d = tiny();
        let mut b = Batcher::new(&d, 3, 0);
        let mut seen = std::collections::HashSet::new();
        // 4 batches = 12 draws > one epoch; first 9 draws (3 batches)
        // must be distinct examples.
        for _ in 0..3 {
            let batch = b.next_batch();
            assert_eq!(batch.x.len(), 6);
            assert_eq!(batch.y.len(), 3);
            for pair in batch.x.chunks(2) {
                assert!(seen.insert(pair[0] as i64), "example repeated within epoch");
            }
        }
    }

    #[test]
    fn batcher_deterministic() {
        let d = tiny();
        let mut a = Batcher::new(&d, 4, 9);
        let mut b = Batcher::new(&d, 4, 9);
        for _ in 0..5 {
            assert_eq!(a.next_batch().y, b.next_batch().y);
        }
    }

    #[test]
    fn eval_batches_cover_exactly_once() {
        let d = tiny();
        let mut total = 0usize;
        for (batch, real) in EvalBatches::new(&d, 4) {
            assert_eq!(batch.y.len(), 4);
            total += real;
        }
        assert_eq!(total, 10);
    }

    #[test]
    fn split_tail() {
        let d = tiny();
        let (tr, te) = d.split_tail(3);
        assert_eq!(tr.n, 7);
        assert_eq!(te.n, 3);
        assert_eq!(te.y, &d.y[7..]);
    }
}
