//! Deterministic PRNGs for data generation and the random-mask control.
//!
//! Hand-rolled (no `rand` crate offline): SplitMix64 for seeding, PCG32
//! (XSH-RR) for streams.  These generate the *synthetic datasets* only;
//! everything PRS-related uses the LFSR module — do not mix them up, the
//! whole point of the paper is that the pruning randomness is an LFSR.

/// SplitMix64 — used to derive well-mixed seeds from small integers.
#[derive(Debug, Clone, Copy)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        SplitMix64 { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// PCG32 (XSH-RR 64/32) — the workhorse stream generator.
#[derive(Debug, Clone, Copy)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    /// Seeded via SplitMix64 so nearby seeds give unrelated streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let state = sm.next_u64();
        let inc = sm.next_u64() | 1;
        let mut pcg = Pcg32 { state, inc };
        pcg.next_u32(); // discard first output (standard warm-up)
        pcg
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old
            .wrapping_mul(6364136223846793005)
            .wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Uniform in [0, bound) via Lemire's multiply-shift with rejection.
    #[inline]
    pub fn next_below(&mut self, bound: u32) -> u32 {
        assert!(bound > 0);
        loop {
            let x = self.next_u32() as u64;
            let m = x * bound as u64;
            let l = m as u32;
            if l >= bound || l >= (u32::MAX - bound + 1) % bound {
                return (m >> 32) as u32;
            }
        }
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1 << 24) as f32)
    }

    /// Standard normal via Box-Muller (cached second value dropped for
    /// simplicity; data-gen throughput is not a bottleneck).
    #[inline]
    pub fn next_normal(&mut self) -> f32 {
        let u1 = self.next_f32().max(1e-7);
        let u2 = self.next_f32();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Pcg32::new(42);
        let mut b = Pcg32::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn next_below_in_range_and_covers() {
        let mut rng = Pcg32::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = rng.next_below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f32_in_unit_interval_and_mixed() {
        let mut rng = Pcg32::new(9);
        let mut sum = 0.0f64;
        for _ in 0..10_000 {
            let v = rng.next_f32();
            assert!((0.0..1.0).contains(&v));
            sum += v as f64;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg32::new(11);
        let n = 20_000;
        let xs: Vec<f32> = (0..n).map(|_| rng.next_normal()).collect();
        let mean = xs.iter().map(|&x| x as f64).sum::<f64>() / n as f64;
        let var = xs.iter().map(|&x| (x as f64 - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn splitmix_distinct_streams() {
        let a: Vec<u32> = {
            let mut r = Pcg32::new(1);
            (0..16).map(|_| r.next_u32()).collect()
        };
        let b: Vec<u32> = {
            let mut r = Pcg32::new(2);
            (0..16).map(|_| r.next_u32()).collect()
        };
        assert_ne!(a, b);
    }
}
