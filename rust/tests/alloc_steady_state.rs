//! Steady-state serving performs **zero heap allocation**: after a
//! couple of warm-up calls (arena buffers, pool-queue capacity, output
//! capacity all grown), `InferenceSession::infer_batch_into` must not
//! allocate at all — inline and pooled alike.
//!
//! Verified with a counting global allocator.  This file deliberately
//! holds a single `#[test]` so no parallel test can allocate on another
//! thread inside the measurement window (worker threads of the sessions
//! under test are quiescent between calls and allocation-free inside
//! them — that is the property being measured).

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use lfsr_prune::serve::{synthetic_lenet300, synthetic_vgg16_scaled, InferenceSession};
use lfsr_prune::sparse::Precision;

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static COUNTER: CountingAlloc = CountingAlloc;

/// Warm `session` then count allocations across `calls` further
/// inferences at the same batch size.
fn allocs_after_warmup(session: &InferenceSession, batch: usize, calls: usize) -> u64 {
    let x = vec![0.25f32; batch * session.model().in_dim()];
    let mut out = Vec::new();
    for _ in 0..3 {
        session.infer_batch_into(&x, batch, &mut out);
    }
    let before = ALLOCS.load(Ordering::SeqCst);
    for _ in 0..calls {
        session.infer_batch_into(&x, batch, &mut out);
    }
    assert_eq!(out.len(), batch * session.model().out_dim());
    ALLOCS.load(Ordering::SeqCst) - before
}

#[test]
fn steady_state_infer_allocates_nothing() {
    // Small but real model: 3 LFSR-pruned layers, padded tail panel at
    // batch 33.
    let batch = 33usize;

    let inline = InferenceSession::new(synthetic_lenet300(0.95, 4, 1), 1);
    let n = allocs_after_warmup(&inline, batch, 10);
    assert_eq!(n, 0, "inline steady-state infer allocated {n} times");

    let pooled = InferenceSession::new(synthetic_lenet300(0.95, 8, 2), 4);
    let n = allocs_after_warmup(&pooled, batch, 10);
    assert_eq!(n, 0, "pooled steady-state infer allocated {n} times");

    // The quantized precision tiers ride the same arena path: each
    // kernel instantiates its value reader once per shard call (the
    // reader is a stack struct borrowing the packed plane — no
    // allocation), and the sub-8-bit tiers decode nibbles/2-bit pairs
    // in place, so every quantized model's steady state is
    // allocation-free too — inline and pooled.
    for tier in [Precision::I8, Precision::I4, Precision::Ternary] {
        let quantized = synthetic_lenet300(0.95, 4, 1).to_precision(tier);
        let q_inline = InferenceSession::new(quantized.clone(), 1);
        let n = allocs_after_warmup(&q_inline, batch, 10);
        assert_eq!(n, 0, "inline {tier} steady-state infer allocated {n} times");
        let q_pooled = InferenceSession::new(quantized, 4);
        let n = allocs_after_warmup(&q_pooled, batch, 10);
        assert_eq!(n, 0, "pooled {tier} steady-state infer allocated {n} times");
    }

    // Conv models ride the same arena: the im2col panel gather reuses
    // the panel buffer, max-pool writes into the resized ping-pong
    // buffer, and the shard fan-out is unchanged — so the scaled VGG-16
    // topology (13 convs + 4 pools + 3 PRS FCs) is allocation-free at
    // steady state too, inline and pooled, at every tier.  Batch 9
    // ensures padded tail panels on the conv virtual rows as well.
    let vgg = synthetic_vgg16_scaled(16, 16, 0.9, 4, 1);
    let conv_inline = InferenceSession::new(vgg.clone(), 1);
    let n = allocs_after_warmup(&conv_inline, 9, 5);
    assert_eq!(n, 0, "inline conv steady-state infer allocated {n} times");
    for tier in [Precision::I8, Precision::I4, Precision::Ternary] {
        let conv_pooled = InferenceSession::new(vgg.to_precision(tier), 4);
        let n = allocs_after_warmup(&conv_pooled, 9, 5);
        assert_eq!(n, 0, "pooled {tier} conv steady-state infer allocated {n} times");
    }

    // The classification path (infer + argmax into warm buffers) is
    // allocation-free too.
    let x = vec![0.25f32; batch * inline.model().in_dim()];
    let (mut logits, mut classes) = (Vec::new(), Vec::new());
    for _ in 0..3 {
        inline.classify_batch_into(&x, batch, &mut logits, &mut classes);
    }
    let before = ALLOCS.load(Ordering::SeqCst);
    for _ in 0..10 {
        inline.classify_batch_into(&x, batch, &mut logits, &mut classes);
    }
    let n = ALLOCS.load(Ordering::SeqCst) - before;
    assert_eq!(classes.len(), batch);
    assert_eq!(n, 0, "steady-state classify allocated {n} times");
}
