//! Steady-state serving performs **zero heap allocation** — with
//! metrics enabled: after a couple of warm-up calls (arena buffers,
//! pool-queue capacity, output capacity all grown),
//! `InferenceSession::infer_batch_into` must not allocate at all —
//! inline and pooled alike, and every session here runs with per-layer
//! span metrics at `sample_every = 1`, so the instrumentation itself is
//! proven allocation-free on the hot path (relaxed atomics into
//! pre-sized histogram storage, nothing else).
//!
//! Verified with the library's own [`CountingAllocator`]
//! (`lfsr_prune::obs`), the same allocator whose running total
//! `ModelRegistry::metrics_text` exports as the
//! `alloc_allocations_total` gauge.  This file deliberately holds a
//! single `#[test]` so no parallel test can allocate on another thread
//! inside the measurement window (worker threads of the sessions under
//! test are quiescent between calls and allocation-free inside them —
//! that is the property being measured).

use lfsr_prune::obs::{total_allocations, CountingAllocator};
use lfsr_prune::serve::{synthetic_lenet300, synthetic_vgg16_scaled, Batcher, InferenceSession};
use lfsr_prune::sparse::{KernelPath, Precision};

#[global_allocator]
static COUNTER: CountingAllocator = CountingAllocator;

/// Build a session with per-layer span metrics on (every call sampled).
fn instrumented(model: lfsr_prune::serve::CompiledModel, workers: usize) -> InferenceSession {
    let mut session = InferenceSession::new(model, workers);
    session.enable_metrics(1);
    session
}

/// Warm `session` then count allocations across `calls` further
/// inferences at the same batch size.
fn allocs_after_warmup(session: &InferenceSession, batch: usize, calls: usize) -> u64 {
    let x = vec![0.25f32; batch * session.model().in_dim()];
    let mut out = Vec::new();
    for _ in 0..3 {
        session.infer_batch_into(&x, batch, &mut out);
    }
    let before = total_allocations();
    for _ in 0..calls {
        session.infer_batch_into(&x, batch, &mut out);
    }
    assert_eq!(out.len(), batch * session.model().out_dim());
    total_allocations() - before
}

#[test]
fn steady_state_infer_allocates_nothing() {
    // Small but real model: 3 LFSR-pruned layers, padded tail panel at
    // batch 33.
    let batch = 33usize;

    let inline = instrumented(synthetic_lenet300(0.95, 4, 1), 1);
    let n = allocs_after_warmup(&inline, batch, 10);
    assert_eq!(n, 0, "inline steady-state infer allocated {n} times");
    // The spans really were recorded — for free.
    let spans = inline.metrics().expect("metrics enabled");
    assert!(spans.layers.iter().all(|l| l.shard_execute.count() >= 13));

    let pooled = instrumented(synthetic_lenet300(0.95, 8, 2), 4);
    let n = allocs_after_warmup(&pooled, batch, 10);
    assert_eq!(n, 0, "pooled steady-state infer allocated {n} times");

    // The quantized precision tiers ride the same arena path: each
    // kernel instantiates its value reader once per shard call (the
    // reader is a stack struct borrowing the packed plane — no
    // allocation), and the sub-8-bit tiers decode nibbles/2-bit pairs
    // in place, so every quantized model's steady state is
    // allocation-free too — inline and pooled.
    for tier in [Precision::I8, Precision::I4, Precision::Ternary] {
        let quantized = synthetic_lenet300(0.95, 4, 1).to_precision(tier);
        let q_inline = instrumented(quantized.clone(), 1);
        let n = allocs_after_warmup(&q_inline, batch, 10);
        assert_eq!(n, 0, "inline {tier} steady-state infer allocated {n} times");
        let q_pooled = instrumented(quantized, 4);
        let n = allocs_after_warmup(&q_pooled, batch, 10);
        assert_eq!(n, 0, "pooled {tier} steady-state infer allocated {n} times");
    }

    // Conv models ride the same arena: the im2col panel gather reuses
    // the panel buffer, max-pool writes into the resized ping-pong
    // buffer, and the shard fan-out is unchanged — so the scaled VGG-16
    // topology (13 convs + 4 pools + 3 PRS FCs) is allocation-free at
    // steady state too, inline and pooled, at every tier.  Batch 9
    // ensures padded tail panels on the conv virtual rows as well.
    let vgg = synthetic_vgg16_scaled(16, 16, 0.9, 4, 1);
    let conv_inline = instrumented(vgg.clone(), 1);
    let n = allocs_after_warmup(&conv_inline, 9, 5);
    assert_eq!(n, 0, "inline conv steady-state infer allocated {n} times");
    for tier in [Precision::I8, Precision::I4, Precision::Ternary] {
        let conv_pooled = instrumented(vgg.to_precision(tier), 4);
        let n = allocs_after_warmup(&conv_pooled, 9, 5);
        assert_eq!(n, 0, "pooled {tier} conv steady-state infer allocated {n} times");
    }

    // The SIMD kernel path shares the arena and the stack-only readers —
    // nothing about vector registers touches the heap — so a session
    // forced onto SIMD must pin *exactly* 0 steady-state allocations
    // too, inline and pooled, f32 and a packed sub-byte tier.  (On a
    // host with no SIMD path ForceSimd resolves to scalar and this
    // re-checks the scalar pin — never skips.)
    let mut simd_inline = instrumented(synthetic_lenet300(0.95, 4, 1), 1);
    simd_inline.set_kernel_path(KernelPath::ForceSimd);
    let n = allocs_after_warmup(&simd_inline, batch, 10);
    assert_eq!(n, 0, "inline SIMD steady-state infer allocated {n} times");
    let mut simd_pooled =
        instrumented(synthetic_lenet300(0.95, 8, 2).to_precision(Precision::I4), 4);
    simd_pooled.set_kernel_path(KernelPath::ForceSimd);
    let n = allocs_after_warmup(&simd_pooled, batch, 10);
    assert_eq!(n, 0, "pooled i4 SIMD steady-state infer allocated {n} times");

    // The classification path (infer + argmax into warm buffers) is
    // allocation-free too.
    let x = vec![0.25f32; batch * inline.model().in_dim()];
    let (mut logits, mut classes) = (Vec::new(), Vec::new());
    for _ in 0..3 {
        inline.classify_batch_into(&x, batch, &mut logits, &mut classes);
    }
    let before = total_allocations();
    for _ in 0..10 {
        inline.classify_batch_into(&x, batch, &mut logits, &mut classes);
    }
    let n = total_allocations() - before;
    assert_eq!(classes.len(), batch);
    assert_eq!(n, 0, "steady-state classify allocated {n} times");

    // Batcher accounting is allocation-free past the first cut: the
    // cut → complete cycle recycles the micro-batch buffers, and every
    // metric write (stage histograms, counters, queue gauge) lands in
    // fixed storage.  Payload allocation belongs to the pushing caller
    // (pinned exactly in `obs_bounded.rs`), so pushes happen before the
    // measurement window here.  The batcher is *bounded* and every
    // request carries a *deadline*: the admission check and the
    // per-request expiry checks at cut time are comparisons on existing
    // state, so the robustness layer rides the zero-allocation path too
    // — and so do the compiled-in (disarmed) failpoints the sessions
    // above fired on every shard.
    let mut batcher = Batcher::new(4, 8);
    batcher.set_max_queue(Some(64));
    let far = std::time::Instant::now() + std::time::Duration::from_secs(3600);
    for i in 0..16u64 {
        batcher.push_with_deadline(i, vec![0.5; 8], Some(far)).unwrap();
    }
    let mb = batcher.next_batch(false).expect("warm cut");
    batcher.complete(mb);
    let before = total_allocations();
    while let Some(mb) = batcher.next_batch(false) {
        batcher.complete(mb);
    }
    let s = batcher.stats();
    let n = total_allocations() - before;
    assert_eq!(s.requests, 16);
    assert_eq!(s.shed, 0, "far-future deadlines must not shed");
    assert_eq!(n, 0, "steady-state cut/complete/stats allocated {n} times");
}
