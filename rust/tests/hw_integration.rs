//! HW-model integration: the full chain mask → engines → energy/area on
//! paper-size layers, and consistency between the closed-form system
//! model and the cycle engines at scale.

use lfsr_prune::hw::{
    self, baseline, compare, estimate_layer, lfsr_engine, simulate_layer, FcDims, HwParams,
    Method, Mode, SparseLayer,
};
use lfsr_prune::mask::prs::{prs_mask, PrsMaskConfig};
use lfsr_prune::data::rng::Pcg32;

#[test]
fn full_lenet300_fc1_exact_simulation_all_grid_points() {
    // Paper-size fc1 (784x300), the whole Table-4 sparsity/bits grid,
    // cycle engines vs closed form.
    let dims = FcDims::new(784, 300);
    for sp in [0.40, 0.70, 0.95] {
        for bits in [4u32, 8] {
            let hp = HwParams::paper_default(bits);
            let est = estimate_layer(dims, sp, Method::Baseline, &hp);
            let sim = simulate_layer(dims, sp, Method::Baseline, &hp, 9);
            let rel = (est.counters.cycles as f64 - sim.counters.cycles as f64).abs()
                / sim.counters.cycles as f64;
            assert!(rel < 0.08, "sp={sp} bits={bits}: cycles rel err {rel}");
            let est_p = estimate_layer(dims, sp, Method::Proposed(Mode::Stream), &hp);
            let sim_p = simulate_layer(dims, sp, Method::Proposed(Mode::Stream), &hp, 9);
            let relp = (est_p.counters.cycles as f64 - sim_p.counters.cycles as f64).abs()
                / sim_p.counters.cycles as f64;
            assert!(relp < 0.10, "sp={sp}: proposed cycles rel err {relp}");
        }
    }
}

#[test]
fn engines_match_reference_at_paper_scale() {
    let (rows, cols) = (800usize, 500usize); // LeNet-5 fc1
    let cfg = PrsMaskConfig::auto(rows, cols, 0xACE1, 0x1D3);
    let mask = prs_mask(rows, cols, 0.9, cfg);
    let mut rng = Pcg32::new(5);
    let layer = SparseLayer {
        rows,
        cols,
        weights: (0..rows * cols).map(|_| rng.next_normal()).collect(),
        mask,
        input: (0..rows).map(|_| rng.next_normal()).collect(),
    };
    let r = layer.reference_output();
    let b = baseline::run(&layer, 4, 8);
    let p = lfsr_engine::run(&layer, cfg, Mode::Ideal);
    for i in 0..cols {
        assert!((b.output[i] - r[i]).abs() < 2e-2, "baseline col {i}");
        assert!((p.output[i] - r[i]).abs() < 2e-2, "proposed col {i}");
    }
}

#[test]
fn whole_paper_grid_savings_shape() {
    // The qualitative claims of Tables 4-5 + Fig 5, asserted end-to-end:
    // proposed always wins; 8b savings ≈ 42-50% at low/mid sparsity;
    // 4b savings smaller at low sparsity but the largest of all at 95%
    // (α inversion); memory reduction within the paper's 1.5-2.9x band.
    for net in hw::layers::paper_networks() {
        let lanes = if net.total_weights() > 1_000_000 { 256 } else { 16 };
        let mut grid = std::collections::BTreeMap::new();
        for sp in [0.40, 0.70, 0.95] {
            for bits in [4u32, 8] {
                let c = compare(&net, sp, bits, Mode::Ideal, lanes);
                assert!(c.power_saving_pct() > 0.0, "{} {sp} {bits}", net.name);
                assert!(c.area_saving_pct() > 0.0, "{} {sp} {bits}", net.name);
                let mr = c.memory_reduction();
                assert!(mr > 1.4 && mr < 3.2, "{}: memory x{mr}", net.name);
                grid.insert((sp.to_bits(), bits), c.power_saving_pct());
            }
        }
        let s40_4 = grid[&(0.40f64.to_bits(), 4)];
        let s40_8 = grid[&(0.40f64.to_bits(), 8)];
        let s95_4 = grid[&(0.95f64.to_bits(), 4)];
        let s95_8 = grid[&(0.95f64.to_bits(), 8)];
        assert!(s40_8 > s40_4, "{}: 8b should win at 40%", net.name);
        assert!(s95_4 > s95_8, "{}: α inversion missing at 95%", net.name);
        assert!(s95_4 > s40_4, "{}: 4b savings must grow with sparsity", net.name);
    }
}

#[test]
fn energy_breakdown_is_dominated_by_memory_reads() {
    // The calibration property the whole Table-4 shape rests on
    // (DESIGN.md §Hardware-Adaptation): array reads >> MAC/buffer costs.
    let em = hw::EnergyModel::default();
    let weight_read = em.sram_read_pj(4096, 8);
    assert!(weight_read > 5.0 * em.mac_8b_pj);
    assert!(weight_read > 10.0 * em.buffer_rw_8b_pj);
    assert!(weight_read > 10.0 * em.lfsr_tick_pj);
}
