//! Artifact-store integration: round-trip bitwise parity across every
//! mask kind and worker/shard count (all four value planes: f32, i8,
//! packed i4, packed ternary), conv/pool layer records (v3) with
//! geometry validation, corruption robustness (typed errors, never
//! panics — malformed scale vectors and crafted conv geometry
//! included), v1/v2/v3 back-compat + version-skew behaviour in both
//! directions (v4-only flags under old stamps are Corrupt naming both
//! versions; re-stamped old fixtures still decode bitwise), verify-mode
//! walk replay, and the paper's artifact-size claim (packed values +
//! O(1) seed/geometry overhead per layer — no index memory, now for the
//! WHOLE VGG-16 including its dense conv stack; the i8/i4/ternary tiers
//! cut the values ~4x/~8x/~16x on top).

use lfsr_prune::hw::layers::vgg16_modified;
use lfsr_prune::mask::prs::PrsMaskConfig;
use lfsr_prune::mask::{magnitude_mask, prune_target, random_mask, Mask};
use lfsr_prune::serve::{
    synthetic_lenet300, synthetic_vgg16_scaled, CompiledLayer, CompiledModel, InferenceSession,
    LayerShape,
};
use lfsr_prune::sparse::{ConvGeom, PoolGeom, Precision};
use lfsr_prune::store::format::{
    dense_record_bytes, file_overhead_bytes, fnv1a64, pool_record_bytes, prs_record_bytes,
    CONV_GEOM_BYTES, POOL_GEOM_BYTES, PRS_EXTRA_BYTES, RECORD_FIXED_BYTES,
};
use lfsr_prune::store::{
    decode_model, encode_model, encode_with_report, export_model, load_model, verify_file,
    LoadOptions, StoreError,
};

use lfsr_prune::data::rng::Pcg32;

const D0: usize = 48;
const D1: usize = 32;
const D2: usize = 10;

fn weights(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Pcg32::new(seed);
    (0..n).map(|_| rng.next_normal()).collect()
}

/// Two-layer model with one mask method applied to both layers (same
/// construction as `serve_integration.rs`).
fn model_for(method: &str, shards: usize) -> CompiledModel {
    let w1 = weights(D0 * D1, 10);
    let w2 = weights(D1 * D2, 11);
    let b1 = weights(D1, 12);
    let b2 = weights(D2, 13);
    let layer = |w: &[f32], b: Vec<f32>, relu: bool, rows: usize, cols: usize, salt: u32| {
        match method {
            "prs" => {
                let cfg = PrsMaskConfig::auto(rows, cols, 3 + salt, 7 + salt);
                CompiledLayer::compile_prs(w, b, relu, rows, cols, 0.8, cfg, shards, 2)
            }
            "magnitude" => {
                let m = magnitude_mask(rows, cols, w, 0.8);
                CompiledLayer::from_mask(w, b, relu, &m, shards)
            }
            "random" => {
                let m = random_mask(rows, cols, 0.8, 99 + salt as u64);
                CompiledLayer::from_mask(w, b, relu, &m, shards)
            }
            other => panic!("unknown method {other}"),
        }
    };
    CompiledModel::new(vec![
        layer(&w1, b1, true, D0, D1, 0),
        layer(&w2, b2, false, D1, D2, 1),
    ])
}

fn tmp_path(name: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("lfsrpack_test_{}_{name}", std::process::id()))
}

fn assert_bitwise_eq(a: &[f32], b: &[f32], ctx: &str) {
    assert_eq!(a.len(), b.len(), "{ctx}: length");
    for (i, (&u, &v)) in a.iter().zip(b).enumerate() {
        assert_eq!(u.to_bits(), v.to_bits(), "{ctx}: logit {i}");
    }
}

// ---------------------------------------------------------------------------
// Round-trip parity
// ---------------------------------------------------------------------------

#[test]
fn roundtrip_bitwise_all_mask_methods_any_workers_shards() {
    let batch = 5;
    let x = weights(batch * D0, 21);
    for method in ["prs", "magnitude", "random"] {
        let original = model_for(method, 3);
        let reference = InferenceSession::new(original.clone(), 1).infer_batch(&x, batch);
        let bytes = encode_model(&original, 2).expect("encode");
        for n_shards in [1usize, 3, 7] {
            for workers in [1usize, 4] {
                let opts = LoadOptions { n_shards, lanes: 2, verify: true, precision: None };
                let loaded = decode_model(&bytes, &opts).expect("decode");
                let got = InferenceSession::new(loaded, workers).infer_batch(&x, batch);
                assert_bitwise_eq(
                    &got,
                    &reference,
                    &format!("{method} shards={n_shards} workers={workers}"),
                );
            }
        }
    }
}

#[test]
fn synthetic_lenet300_export_load_parity() {
    // The acceptance case: inference through an exported-then-loaded
    // artifact equals inference through CompiledModel::compile_prs
    // bit-for-bit, for any worker/shard count.
    let original = synthetic_lenet300(0.9, 4, 2);
    let batch = 3;
    let x = weights(batch * 784, 31);
    let reference = InferenceSession::new(original.clone(), 1).infer_batch(&x, batch);
    let path = tmp_path("lenet300");
    let report = export_model(&original, &path, 2).expect("export");
    assert_eq!(report.layers, 3);
    for (n_shards, workers) in [(1usize, 1usize), (5, 3), (16, 2)] {
        let opts = LoadOptions { n_shards, lanes: 2, verify: false, precision: None };
        let loaded = load_model(&path, &opts).expect("load");
        assert_eq!(loaded.nnz(), original.nnz());
        let got = InferenceSession::new(loaded, workers).infer_batch(&x, batch);
        assert_bitwise_eq(&got, &reference, &format!("shards={n_shards} workers={workers}"));
    }
    let v = verify_file(&path, 2).expect("verify");
    assert_eq!(v.prs_layers_verified, 3);
    let _ = std::fs::remove_file(&path);
}

// ---------------------------------------------------------------------------
// Corruption robustness: typed errors, never panics
// ---------------------------------------------------------------------------

fn opts() -> LoadOptions {
    LoadOptions { n_shards: 2, lanes: 1, verify: false, precision: None }
}

#[test]
fn flipped_byte_anywhere_is_a_checksum_error() {
    let bytes = encode_model(&model_for("prs", 2), 1).expect("encode");
    // Flip one byte in the value payload and one in a record header.
    for at in [bytes.len() / 2, 30] {
        let mut bad = bytes.clone();
        bad[at] ^= 0x40;
        match decode_model(&bad, &opts()) {
            Err(StoreError::ChecksumMismatch { .. }) => {}
            other => panic!("byte {at}: expected ChecksumMismatch, got {other:?}"),
        }
    }
}

#[test]
fn truncated_file_is_a_truncation_error() {
    let bytes = encode_model(&model_for("random", 2), 1).expect("encode");
    for keep in [0, 10, 23, bytes.len() / 2, bytes.len() - 1] {
        match decode_model(&bytes[..keep], &opts()) {
            Err(StoreError::Truncated { got, .. }) => assert_eq!(got, keep as u64),
            other => panic!("keep {keep}: expected Truncated, got {other:?}"),
        }
    }
}

#[test]
fn wrong_version_and_magic_are_typed_errors() {
    let bytes = encode_model(&model_for("magnitude", 1), 1).expect("encode");
    let mut wrong_version = bytes.clone();
    wrong_version[8] = 99; // version field, checked before the checksum
    match decode_model(&wrong_version, &opts()) {
        Err(StoreError::UnsupportedVersion { found: 99 }) => {}
        other => panic!("expected UnsupportedVersion, got {other:?}"),
    }
    let mut wrong_magic = bytes;
    wrong_magic[0] = b'X';
    assert!(matches!(decode_model(&wrong_magic, &opts()), Err(StoreError::BadMagic)));
    assert!(matches!(
        decode_model(b"LFSRPACK", &opts()),
        Err(StoreError::Truncated { .. })
    ));
}

/// Patch `bytes[at..at+len]`, then re-stamp the trailing checksum so the
/// corruption survives the checksum gate and must be caught by field
/// validation.
fn patch_and_restamp(bytes: &[u8], at: usize, patch: &[u8]) -> Vec<u8> {
    let mut out = bytes.to_vec();
    out[at..at + patch.len()].copy_from_slice(patch);
    let end = out.len() - 8;
    let crc = fnv1a64(&out[..end]);
    out[end..].copy_from_slice(&crc.to_le_bytes());
    out
}

#[test]
fn crafted_fields_are_corrupt_errors_not_panics() {
    let bytes = encode_model(&model_for("prs", 2), 1).expect("encode");
    let record0 = (8 + 4 + 4 + 8) as usize; // first byte of layer 0
    // Unknown mask kind tag.
    match decode_model(&patch_and_restamp(&bytes, record0, &[7]), &opts()) {
        Err(StoreError::Corrupt { detail }) => assert!(detail.contains("kind"), "{detail}"),
        other => panic!("expected Corrupt, got {other:?}"),
    }
    // Unknown flags.
    match decode_model(&patch_and_restamp(&bytes, record0 + 1, &[0xFF]), &opts()) {
        Err(StoreError::Corrupt { .. }) => {}
        other => panic!("expected Corrupt, got {other:?}"),
    }
    // Zero rows.
    match decode_model(&patch_and_restamp(&bytes, record0 + 2, &0u32.to_le_bytes()), &opts()) {
        Err(StoreError::Corrupt { .. }) => {}
        other => panic!("expected Corrupt, got {other:?}"),
    }
    // nnz inflated beyond rows*cols.
    let nnz_at = record0 + 10;
    match decode_model(
        &patch_and_restamp(&bytes, nnz_at, &u64::MAX.to_le_bytes()),
        &opts(),
    ) {
        Err(StoreError::Corrupt { .. }) => {}
        other => panic!("expected Corrupt, got {other:?}"),
    }
    // Row LFSR width changed out from under its stored polynomial.
    let widths_at = record0 + RECORD_FIXED_BYTES as usize;
    match decode_model(&patch_and_restamp(&bytes, widths_at, &[2]), &opts()) {
        Err(StoreError::Corrupt { .. }) => {}
        other => panic!("expected Corrupt, got {other:?}"),
    }
    // Layer count of zero.
    match decode_model(&patch_and_restamp(&bytes, 12, &0u32.to_le_bytes()), &opts()) {
        Err(StoreError::Corrupt { .. }) => {}
        other => panic!("expected Corrupt, got {other:?}"),
    }
}

#[test]
fn verify_catches_reseeded_artifact() {
    let bytes = encode_model(&model_for("prs", 2), 1).expect("encode");
    // seed_row of layer 0 sits after the fixed record part, widths, and
    // polynomials.
    let seed_at = (8 + 4 + 4 + 8) + RECORD_FIXED_BYTES as usize + 2 + 8;
    let orig_seed = u32::from_le_bytes(bytes[seed_at..seed_at + 4].try_into().unwrap());
    let reseeded = patch_and_restamp(&bytes, seed_at, &(orig_seed + 1).to_le_bytes());
    // Without verify the file is structurally fine (same dims, same keep
    // budget) — it loads, silently packing values for the WRONG walk...
    let loaded = decode_model(&reseeded, &opts()).expect("structurally valid");
    assert_eq!(loaded.nnz(), model_for("prs", 2).nnz());
    // ...which is exactly what verify exists to catch: the replayed walk
    // hash no longer matches the stored packing.
    let strict = LoadOptions { n_shards: 2, lanes: 1, verify: true, precision: None };
    match decode_model(&reseeded, &strict) {
        Err(StoreError::WalkMismatch { layer: 0, .. }) => {}
        other => panic!("expected WalkMismatch, got {other:?}"),
    }
}

// ---------------------------------------------------------------------------
// Precision tiers: v2 round-trip, v1 back-compat, malformed scales
// ---------------------------------------------------------------------------

#[test]
fn quantized_roundtrip_bitwise_all_mask_methods_any_workers_shards() {
    // The v2/v4 acceptance case: a quantized-tier model encodes its raw
    // codes + scales (no dequantization round trip; sub-8-bit codes are
    // repacked shard-locally on load), so a load must reproduce the
    // exact logits of the in-memory quantized model — any shard or
    // worker count, every mask family, every quantized tier.
    let batch = 5;
    let x = weights(batch * D0, 61);
    for tier in [Precision::I8, Precision::I4, Precision::Ternary] {
        for method in ["prs", "magnitude", "random"] {
            let original = model_for(method, 3).to_precision(tier);
            let reference = InferenceSession::new(original.clone(), 1).infer_batch(&x, batch);
            let bytes = encode_model(&original, 2).expect("encode");
            for n_shards in [1usize, 3, 7] {
                for workers in [1usize, 4] {
                    let opts =
                        LoadOptions { n_shards, lanes: 2, verify: true, precision: None };
                    let loaded = decode_model(&bytes, &opts).expect("decode");
                    assert_eq!(loaded.uniform_precision(), Some(tier));
                    let got = InferenceSession::new(loaded, workers).infer_batch(&x, batch);
                    assert_bitwise_eq(
                        &got,
                        &reference,
                        &format!("{tier} {method} shards={n_shards} workers={workers}"),
                    );
                }
            }
        }
    }
}

#[test]
fn quantized_lenet300_artifact_cuts_value_bytes_4x() {
    let f = synthetic_lenet300(0.9, 2, 1);
    let q = f.to_precision(Precision::I8);
    let (fb, fr) = encode_with_report(&f, 1).expect("f32 encode");
    let (qb, qr) = encode_with_report(&q, 1).expect("i8 encode");
    // Values shrink exactly 4x (4 B -> 1 B per kept entry); the new cost
    // is one 4 B scale per column; seeds/index state are unchanged.
    assert_eq!(fr.value_bytes, 4 * qr.value_bytes);
    let cols: u64 = q.layers.iter().map(|l| l.cols as u64).sum();
    assert_eq!(qr.scale_bytes, 4 * cols);
    assert_eq!(fr.seed_bytes, qr.seed_bytes);
    assert!(qb.len() < fb.len());
    // And a mixed-tier model (quantized trunk, f32 head) round-trips
    // with per-layer tags.
    let mut mixed = f.clone();
    mixed.layers[0] = mixed.layers[0].to_precision(Precision::I8);
    mixed.layers[1] = mixed.layers[1].to_precision(Precision::I8);
    let bytes = encode_model(&mixed, 1).expect("mixed encode");
    let loaded = decode_model(&bytes, &opts()).expect("mixed decode");
    assert_eq!(loaded.uniform_precision(), None);
    assert_eq!(loaded.layers[0].precision, Precision::I8);
    assert_eq!(loaded.layers[2].precision, Precision::F32);
}

#[test]
fn v1_artifact_still_loads_as_f32() {
    // Fixture: a v1 byte stream.  v1..v3 have the identical record
    // layout for f32 FC planes (the only records v1 had), so the
    // canonical way to produce one is to stamp version 1 over an f32
    // FC-only encode and re-checksum — the payload bytes are untouched.
    // (The magnitude model is NOT dense, so no v3 kind-3 record appears.)
    let batch = 4;
    let x = weights(batch * D0, 71);
    for method in ["prs", "magnitude"] {
        let model = model_for(method, 2);
        let v2 = encode_model(&model, 1).expect("encode");
        assert_eq!(u32::from_le_bytes(v2[8..12].try_into().unwrap()), 4, "writer is at v4");
        let v1 = patch_and_restamp(&v2, 8, &1u32.to_le_bytes());
        let strict = LoadOptions { n_shards: 3, lanes: 1, verify: true, precision: None };
        let loaded = decode_model(&v1, &strict).expect("v1 decodes");
        assert_eq!(loaded.uniform_precision(), Some(Precision::F32));
        let got = InferenceSession::new(loaded, 2).infer_batch(&x, batch);
        let reference = InferenceSession::new(model, 1).infer_batch(&x, batch);
        assert_bitwise_eq(&got, &reference, &format!("v1 {method}"));
        // A v1 load can still opt into the i8 tier at load time.
        let quantizing = LoadOptions {
            n_shards: 3,
            lanes: 1,
            verify: false,
            precision: Some(Precision::I8),
        };
        let q = decode_model(&v1, &quantizing).expect("v1 + load-time i8");
        assert_eq!(q.uniform_precision(), Some(Precision::I8));
    }
}

#[test]
fn v1_artifact_with_i8_flag_is_corrupt_not_misread() {
    // The i8 flag did not exist in v1: a v1 header claiming it is
    // corrupt (re-stamped so the checksum gate cannot catch it first).
    let q = model_for("prs", 2).to_precision(Precision::I8);
    let v2 = encode_model(&q, 1).expect("encode");
    let v1 = patch_and_restamp(&v2, 8, &1u32.to_le_bytes());
    match decode_model(&v1, &opts()) {
        Err(StoreError::Corrupt { detail }) => {
            assert!(detail.contains("v2") && detail.contains("v1"), "{detail}");
        }
        other => panic!("expected Corrupt, got {other:?}"),
    }
}

#[test]
fn version_skew_error_names_the_supported_range() {
    // A future v5 artifact must fail with a message an operator can act
    // on: the found version AND the v1..=v4 range this build reads.
    let bytes = encode_model(&model_for("prs", 1), 1).expect("encode");
    let v5 = patch_and_restamp(&bytes, 8, &5u32.to_le_bytes());
    match decode_model(&v5, &opts()) {
        Err(e @ StoreError::UnsupportedVersion { found: 5 }) => {
            let msg = e.to_string();
            assert!(msg.contains('5'), "{msg}");
            assert!(msg.contains("v1") && msg.contains("v4"), "{msg}");
        }
        other => panic!("expected UnsupportedVersion, got {other:?}"),
    }
}

#[test]
fn v4_records_stamped_as_older_versions_are_corrupt_not_misread() {
    // Version skew, new-format side: the i4/ternary precision flags did
    // not exist before v4 — a v1/v2/v3 header claiming them must fail
    // with BOTH versions named, never a silent misparse of the packed
    // payload.
    for tier in [Precision::I4, Precision::Ternary] {
        let q = model_for("prs", 2).to_precision(tier);
        let v4 = encode_model(&q, 1).expect("encode");
        for old in [1u32, 2, 3] {
            let stamped = patch_and_restamp(&v4, 8, &old.to_le_bytes());
            match decode_model(&stamped, &opts()) {
                Err(StoreError::Corrupt { detail }) => {
                    assert!(
                        detail.contains("v4") && detail.contains(&format!("v{old}")),
                        "{tier}@v{old}: {detail}"
                    );
                }
                other => panic!("{tier}@v{old}: expected Corrupt, got {other:?}"),
            }
        }
    }
}

#[test]
fn v3_fixture_still_decodes_every_v3_record_kind() {
    // Version skew, old-format side: v3 byte streams (conv geometry,
    // pool, dense records, f32/i8 planes — everything except the v4
    // packed planes) are laid out identically under the v4 reader, so a
    // re-stamped v3 fixture must decode bitwise.
    let batch = 4;
    let in_dim = 6 * 6 * 2;
    let x = weights(batch * in_dim, 77);
    for tier in [Precision::F32, Precision::I8] {
        let model = conv_model(2).to_precision(tier);
        let v4 = encode_model(&model, 1).expect("encode");
        let v3 = patch_and_restamp(&v4, 8, &3u32.to_le_bytes());
        let strict = LoadOptions { n_shards: 3, lanes: 1, verify: true, precision: None };
        let loaded = decode_model(&v3, &strict).expect("v3 decodes");
        assert_eq!(loaded.uniform_precision(), Some(tier));
        let got = InferenceSession::new(loaded, 2).infer_batch(&x, batch);
        let reference = InferenceSession::new(model, 1).infer_batch(&x, batch);
        assert_bitwise_eq(&got, &reference, &format!("v3 {tier}"));
        // A v3 load can still opt into a v4 tier at load time — the skew
        // lives only in the file, not in the serving stack.
        let quantizing = LoadOptions {
            n_shards: 3,
            lanes: 1,
            verify: false,
            precision: Some(Precision::Ternary),
        };
        let t = decode_model(&v3, &quantizing).expect("v3 + load-time ternary");
        assert_eq!(t.uniform_precision(), Some(Precision::Ternary));
    }
}

// ---------------------------------------------------------------------------
// v3: conv / pool / dense records
// ---------------------------------------------------------------------------

/// Small conv chain: dense 3x3 SAME conv -> 2x2 pool -> PRS conv -> PRS
/// FC head.  Every v3 record kind in one model.
fn conv_model(shards: usize) -> CompiledModel {
    let mut rng = Pcg32::new(57);
    let g1 = ConvGeom::same3x3(6, 6, 2, 3);
    let w1: Vec<f32> = (0..g1.patch_len() * 3).map(|_| rng.next_normal() * 0.2).collect();
    let b1: Vec<f32> = (0..3).map(|_| rng.next_normal() * 0.1).collect();
    let g2 = ConvGeom { in_h: 3, in_w: 3, in_c: 3, out_c: 4, kernel: 2, stride: 1, pad: 0 };
    let w2: Vec<f32> = (0..g2.patch_len() * 4).map(|_| rng.next_normal() * 0.2).collect();
    let cfg2 = PrsMaskConfig::auto(g2.patch_len(), 4, 5, 9);
    let flat = g2.out_len();
    let w3: Vec<f32> = (0..flat * 6).map(|_| rng.next_normal() * 0.2).collect();
    let b3: Vec<f32> = (0..6).map(|_| rng.next_normal() * 0.1).collect();
    let cfg3 = PrsMaskConfig::auto(flat, 6, 7, 11);
    CompiledModel::new(vec![
        CompiledLayer::conv_from_mask(&w1, b1, true, &Mask::dense(g1.patch_len(), 3), g1, shards),
        CompiledLayer::maxpool(PoolGeom::pool2(6, 6, 3)),
        CompiledLayer::compile_conv_prs(&w2, Vec::new(), true, g2, 0.5, cfg2, shards, 1),
        CompiledLayer::compile_prs(&w3, b3, false, flat, 6, 0.5, cfg3, shards, 1),
    ])
}

#[test]
fn conv_model_roundtrip_bitwise_every_tier_any_workers_shards() {
    // The v3/v4 acceptance case: a conv-capable model (dense conv, pool,
    // PRS conv, PRS FC) round-trips to the exact same logits for any
    // shard/worker composition, in all four precision tiers.
    let batch = 5;
    let in_dim = 6 * 6 * 2;
    let x = weights(batch * in_dim, 81);
    for tier in [Precision::F32, Precision::I8, Precision::I4, Precision::Ternary] {
        let original = conv_model(3).to_precision(tier);
        let reference = InferenceSession::new(original.clone(), 1).infer_batch(&x, batch);
        let bytes = encode_model(&original, 2).expect("encode");
        for n_shards in [1usize, 3, 7] {
            for workers in [1usize, 4] {
                let opts = LoadOptions { n_shards, lanes: 2, verify: true, precision: None };
                let loaded = decode_model(&bytes, &opts).expect("decode");
                assert_eq!(loaded.layer_kind_counts().conv, 2);
                assert_eq!(loaded.layer_kind_counts().pool, 1);
                let got = InferenceSession::new(loaded, workers).infer_batch(&x, batch);
                assert_bitwise_eq(
                    &got,
                    &reference,
                    &format!("conv {tier} shards={n_shards} workers={workers}"),
                );
            }
        }
    }
}

#[test]
fn scaled_vgg16_roundtrip_bitwise_and_size_model_exact() {
    // The flagship topology end to end through the store: 13 convs, 4
    // pools, 3 PRS FCs — encoded size matches the record-size model
    // EXACTLY, and a load serves bitwise-identical logits.
    let model = synthetic_vgg16_scaled(16, 16, 0.9, 2, 1);
    let (bytes, report) = encode_with_report(&model, 2).expect("encode");
    let predicted: u64 = file_overhead_bytes()
        + model
            .layers
            .iter()
            .map(|l| match l.shape {
                LayerShape::MaxPool(_) => pool_record_bytes(),
                LayerShape::Conv(_) => {
                    dense_record_bytes(l.nnz() as u64, l.bias.len() as u64, true)
                }
                LayerShape::Fc => prs_record_bytes(l.nnz() as u64, l.bias.len() as u64),
            })
            .sum::<u64>();
    assert_eq!(bytes.len() as u64, predicted);
    assert_eq!(report.total_bytes, predicted);
    assert_eq!(report.explicit_index_bytes, 0, "dense convs store no positions");
    let batch = 2;
    let x = weights(batch * model.in_dim(), 83);
    let reference = InferenceSession::new(model.clone(), 1).infer_batch(&x, batch);
    let opts = LoadOptions { n_shards: 3, lanes: 2, verify: true, precision: None };
    let loaded = decode_model(&bytes, &opts).expect("decode");
    let got = InferenceSession::new(loaded, 2).infer_batch(&x, batch);
    assert_bitwise_eq(&got, &reference, "scaled vgg16");
}

#[test]
fn scaled_vgg16_sub8_roundtrip_bitwise_per_tier() {
    // One VGG-scaled parity row per new tier: the conv stack inherits
    // the packed planes through im2col, and an exported-then-loaded
    // quantized VGG serves the exact bits of the in-memory model.
    let batch = 2;
    for tier in [Precision::I4, Precision::Ternary] {
        let model = synthetic_vgg16_scaled(16, 16, 0.9, 2, 1).to_precision(tier);
        let x = weights(batch * model.in_dim(), 87);
        let reference = InferenceSession::new(model.clone(), 1).infer_batch(&x, batch);
        let bytes = encode_model(&model, 2).expect("encode");
        let opts = LoadOptions { n_shards: 3, lanes: 2, verify: true, precision: None };
        let loaded = decode_model(&bytes, &opts).expect("decode");
        assert_eq!(loaded.uniform_precision(), Some(tier));
        let got = InferenceSession::new(loaded, 2).infer_batch(&x, batch);
        assert_bitwise_eq(&got, &reference, &format!("scaled vgg16 {tier}"));
    }
}

#[test]
fn sub8_artifact_value_bytes_cut_8x_and_16x() {
    // The on-disk counterpart of the in-memory footprint pins: i4 halves
    // the i8 code payload (two per byte), ternary halves it again (four
    // per byte), the scale vectors and seed/index state are identical
    // across all quantized tiers.
    let f = synthetic_lenet300(0.9, 2, 1);
    let (_, fr) = encode_with_report(&f, 1).expect("f32 encode");
    let (_, r8) = encode_with_report(&f.to_precision(Precision::I8), 1).expect("i8");
    let (_, r4) = encode_with_report(&f.to_precision(Precision::I4), 1).expect("i4");
    let (_, rt) = encode_with_report(&f.to_precision(Precision::Ternary), 1).expect("ternary");
    let nnz: u64 = f.nnz() as u64;
    assert_eq!(fr.value_bytes, 4 * nnz);
    assert_eq!(r8.value_bytes, nnz);
    // Per layer the packed length rounds up; totals stay within a few
    // tail bytes of the ideal 2x/4x code cuts.
    let i4_ideal: u64 = f.layers.iter().map(|l| (l.nnz() as u64 + 1) / 2).sum();
    let t_ideal: u64 = f.layers.iter().map(|l| (l.nnz() as u64 + 3) / 4).sum();
    assert_eq!(r4.value_bytes, i4_ideal);
    assert_eq!(rt.value_bytes, t_ideal);
    assert_eq!(r8.scale_bytes, r4.scale_bytes);
    assert_eq!(r8.scale_bytes, rt.scale_bytes);
    assert_eq!(fr.seed_bytes, rt.seed_bytes);
    let ratio4 = fr.value_bytes as f64 / r4.value_bytes as f64;
    let ratio_t = fr.value_bytes as f64 / rt.value_bytes as f64;
    assert!(ratio4 > 7.9 && ratio4 <= 8.0, "i4 values cut {ratio4}");
    assert!(ratio_t > 15.8 && ratio_t <= 16.0, "ternary values cut {ratio_t}");
}

#[test]
fn v2_fixture_still_decodes_fc_and_i8() {
    // v2 files (FC records, optional i8 plane) must keep loading: the FC
    // record layout is unchanged between v2 and v3, so re-stamping an
    // FC-only encode to version 2 produces a canonical v2 byte stream.
    let batch = 4;
    let x = weights(batch * D0, 73);
    for tier in [Precision::F32, Precision::I8] {
        let model = model_for("prs", 2).to_precision(tier);
        let v3 = encode_model(&model, 1).expect("encode");
        let v2 = patch_and_restamp(&v3, 8, &2u32.to_le_bytes());
        let strict = LoadOptions { n_shards: 3, lanes: 1, verify: true, precision: None };
        let loaded = decode_model(&v2, &strict).expect("v2 decodes");
        assert_eq!(loaded.uniform_precision(), Some(tier));
        let got = InferenceSession::new(loaded, 2).infer_batch(&x, batch);
        let reference = InferenceSession::new(model, 1).infer_batch(&x, batch);
        assert_bitwise_eq(&got, &reference, &format!("v2 {tier}"));
    }
}

#[test]
fn v3_records_stamped_as_older_versions_are_corrupt_not_misread() {
    // The version-skew story from the reader's side: conv geometry,
    // pool records, and dense records did not exist before v3 — a v1/v2
    // header claiming them must fail with BOTH versions named, never a
    // silent misparse.
    let conv = encode_model(&conv_model(2), 1).expect("encode conv");
    let v2 = patch_and_restamp(&conv, 8, &2u32.to_le_bytes());
    match decode_model(&v2, &opts()) {
        Err(StoreError::Corrupt { detail }) => {
            assert!(detail.contains("v3") && detail.contains("v2"), "{detail}");
        }
        other => panic!("conv@v2: expected Corrupt, got {other:?}"),
    }
    // A model starting with a pool record: kind 2 under v2.
    let pool_first = CompiledModel::new(vec![CompiledLayer::maxpool(PoolGeom::pool2(4, 4, 2))]);
    let bytes = encode_model(&pool_first, 1).expect("encode pool");
    let v2 = patch_and_restamp(&bytes, 8, &2u32.to_le_bytes());
    match decode_model(&v2, &opts()) {
        Err(StoreError::Corrupt { detail }) => {
            assert!(detail.contains("v3") && detail.contains("v2"), "{detail}");
        }
        other => panic!("pool@v2: expected Corrupt, got {other:?}"),
    }
    // A dense FC layer: kind 3 under v1.
    let w = weights(8 * 3, 85);
    let dense = CompiledModel::new(vec![CompiledLayer::from_mask(
        &w,
        Vec::new(),
        false,
        &Mask::dense(8, 3),
        1,
    )]);
    let bytes = encode_model(&dense, 1).expect("encode dense");
    let v1 = patch_and_restamp(&bytes, 8, &1u32.to_le_bytes());
    match decode_model(&v1, &opts()) {
        Err(StoreError::Corrupt { detail }) => {
            assert!(detail.contains("v3") && detail.contains("v1"), "{detail}");
        }
        other => panic!("dense@v1: expected Corrupt, got {other:?}"),
    }
}

#[test]
fn corrupted_conv_geometry_fields_are_typed_errors() {
    // conv_model layer 0 is a dense conv (kind 3 + FLAG_CONV): its
    // geometry block sits right after the fixed record part.
    let bytes = encode_model(&conv_model(2), 1).expect("encode");
    let record0 = (8 + 4 + 4 + 8) as usize;
    let geom = record0 + RECORD_FIXED_BYTES as usize;
    let (in_h_at, in_w_at, in_c_at) = (geom, geom + 4, geom + 8);
    let (kernel_at, stride_at, pad_at) = (geom + 12, geom + 13, geom + 14);
    let cases: Vec<(usize, Vec<u8>, &str)> = vec![
        (kernel_at, vec![0u8], "kernel zero"),
        (stride_at, vec![0u8], "stride zero"),
        (pad_at, vec![9u8], "pad >= kernel"),
        (in_h_at, 0u32.to_le_bytes().to_vec(), "zero input height"),
        (in_w_at, u32::MAX.to_le_bytes().to_vec(), "input width beyond MAX_DIM"),
        // in_c changed => kernel^2*in_c no longer matches the record's
        // rows field.
        (in_c_at, 7u32.to_le_bytes().to_vec(), "geometry/rows mismatch"),
    ];
    for (at, patch, what) in cases {
        let bad = patch_and_restamp(&bytes, at, &patch);
        match decode_model(&bad, &opts()) {
            Err(StoreError::Corrupt { detail }) => {
                assert!(detail.contains("layer 0"), "{what}: {detail}");
            }
            other => panic!("{what}: expected Corrupt, got {other:?}"),
        }
    }
    // Overflow attack: all geometry fields individually satisfy the
    // MAX_DIM bound, every per-field check passes (kernel 1, pad 0,
    // rows = kernel^2 * in_c = 2^26, rows*cols within MAX_CELLS), but
    // in_h*in_w*in_c = 2^64 — a wrapping multiply would read it as 0 and
    // let the loader accept a layer whose first inference must allocate
    // ~petabytes of im2col panels.  The checked-volume guard must refuse.
    let mut patched = patch_and_restamp(&bytes, record0 + 2, &(1u32 << 26).to_le_bytes());
    patched = patch_and_restamp(&patched, in_h_at, &(1u32 << 19).to_le_bytes());
    patched = patch_and_restamp(&patched, in_w_at, &(1u32 << 19).to_le_bytes());
    patched = patch_and_restamp(&patched, in_c_at, &(1u32 << 26).to_le_bytes());
    patched = patch_and_restamp(&patched, kernel_at, &[1u8]);
    patched = patch_and_restamp(&patched, pad_at, &[0u8]);
    match decode_model(&patched, &opts()) {
        Err(StoreError::Corrupt { detail }) => {
            assert!(
                detail.contains("layer 0") && detail.contains("exceeds"),
                "{detail}"
            );
        }
        other => panic!("volume overflow: expected Corrupt, got {other:?}"),
    }
    // Pool geometry: corrupt the kernel of the pool record (layer 1).
    // Its record starts after layer 0's record.
    let model = conv_model(2);
    let layer0 = &model.layers[0];
    let layer0_bytes =
        dense_record_bytes(layer0.nnz() as u64, layer0.bias.len() as u64, true) as usize;
    let pool_geom = record0 + layer0_bytes + RECORD_FIXED_BYTES as usize;
    let bad = patch_and_restamp(&bytes, pool_geom + 12, &[0u8]);
    match decode_model(&bad, &opts()) {
        Err(StoreError::Corrupt { detail }) => {
            assert!(detail.contains("layer 1"), "{detail}");
        }
        other => panic!("pool kernel zero: expected Corrupt, got {other:?}"),
    }
    // The untouched artifact still loads.
    decode_model(&bytes, &opts()).expect("clean conv artifact loads");
}

#[test]
fn vgg16_whole_network_artifact_overhead_is_constant_per_layer() {
    // The conv-capable artifact-size pin at the paper's FULL dims (pure
    // arithmetic — no 68 MB encode in the test suite): the whole modified
    // VGG-16 — 13 dense convs, 4 pools, 3 PRS FCs at 90% sparsity —
    // stores its ~17M values with under 1 KiB of total index/geometry/
    // framing overhead.  CSC-style positions for the same network would
    // cost ~65 MB.
    let net = vgg16_modified();
    let sp = 0.9;
    let value_bytes = net.value_bytes(sp, Precision::F32);
    assert!(value_bytes > 60_000_000, "whole network is ~68 MB of values: {value_bytes}");
    let artifact_bytes: u64 = file_overhead_bytes()
        + net
            .conv_layers
            .iter()
            .map(|d| dense_record_bytes(d.size() as u64, 0, true))
            .sum::<u64>()
        + 4 * pool_record_bytes()
        + net
            .layers
            .iter()
            .map(|d| {
                let kept = (d.size() - prune_target(d.rows, d.cols, sp)) as u64;
                prs_record_bytes(kept, 0)
            })
            .sum::<u64>();
    let overhead = artifact_bytes - value_bytes;
    let expected = file_overhead_bytes()
        + 13 * (RECORD_FIXED_BYTES + CONV_GEOM_BYTES)
        + 4 * (RECORD_FIXED_BYTES + POOL_GEOM_BYTES)
        + 3 * (RECORD_FIXED_BYTES + PRS_EXTRA_BYTES);
    assert_eq!(overhead, expected);
    assert!(overhead < 1024, "whole-network overhead {overhead}");
    assert!((overhead as f64) < 1e-4 * value_bytes as f64);
}

#[test]
fn malformed_scales_are_typed_errors() {
    // Checksum-valid bytes whose scale vector is poison (NaN / -1 / inf)
    // must come back as BadScale naming layer and column — never load.
    let q = model_for("prs", 2).to_precision(Precision::I8);
    let bytes = encode_model(&q, 1).expect("encode");
    // Layer 0 scale vector starts after the fixed record, PRS extras,
    // and the bias payload (D1 f32s).
    let record0 = (8 + 4 + 4 + 8) as usize;
    let scales_at = record0 + (RECORD_FIXED_BYTES + PRS_EXTRA_BYTES) as usize + 4 * D1;
    for (bad, name) in [
        (f32::NAN, "NaN"),
        (f32::NEG_INFINITY, "-inf"),
        (-1.0f32, "negative"),
    ] {
        let patched = patch_and_restamp(&bytes, scales_at + 4 * 2, &bad.to_le_bytes());
        match decode_model(&patched, &opts()) {
            Err(StoreError::BadScale { layer: 0, column: 2, value }) => {
                assert!(value.is_nan() || value < 0.0, "{name}: value {value}");
            }
            other => panic!("{name}: expected BadScale, got {other:?}"),
        }
    }
    // Zero is legal (all-zero column) — the untouched artifact loads.
    decode_model(&bytes, &opts()).expect("clean quantized artifact loads");
}

// ---------------------------------------------------------------------------
// The paper's artifact-size claim
// ---------------------------------------------------------------------------

#[test]
fn exported_file_size_matches_size_model_exactly() {
    let model = model_for("prs", 2);
    let (bytes, report) = encode_with_report(&model, 1).expect("encode");
    let predicted: u64 = file_overhead_bytes()
        + model
            .layers
            .iter()
            .map(|l| prs_record_bytes(l.nnz() as u64, l.bias.len() as u64))
            .sum::<u64>();
    assert_eq!(bytes.len() as u64, predicted);
    assert_eq!(report.total_bytes, predicted);
}

#[test]
fn vgg16_artifact_overhead_is_seeds_only() {
    // Modified VGG-16 FC layers at the paper's ~10x compression rate
    // (90% sparsity): on-disk index overhead must be O(layers) seed
    // bytes, with the payload exactly the packed non-zero values.
    let net = vgg16_modified();
    let sp = 0.9;
    let value_bytes = net.fc_param_bytes(sp);
    assert!(value_bytes > 8_000_000, "VGG FC values should be MBs: {value_bytes}");
    let artifact_bytes: u64 = file_overhead_bytes()
        + net
            .layers
            .iter()
            .map(|d| {
                let kept = (d.size() - prune_target(d.rows, d.cols, sp)) as u64;
                prs_record_bytes(kept, 0)
            })
            .sum::<u64>();
    let overhead = artifact_bytes - value_bytes;
    let per_layer = RECORD_FIXED_BYTES + PRS_EXTRA_BYTES;
    assert_eq!(overhead, file_overhead_bytes() + net.layers.len() as u64 * per_layer);
    // O(1) per layer, O(1) per file: under 64 B each, ~200 B total for a
    // 9.2 MB payload — versus O(nnz) index entries for a CSC artifact
    // (2.29M 13-bit indices ≈ 3.7 MB at this rate).
    assert!(per_layer < 64, "per-layer overhead {per_layer}");
    assert!(overhead < 256, "total index+framing overhead {overhead}");
    assert!((overhead as f64) < 1e-4 * value_bytes as f64);
}
