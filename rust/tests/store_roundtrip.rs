//! Artifact-store integration: round-trip bitwise parity across every
//! mask kind and worker/shard count (f32 and i8 value planes),
//! corruption robustness (typed errors, never panics — malformed scale
//! vectors included), v1 back-compat + version-skew behaviour,
//! verify-mode walk replay, and the paper's artifact-size claim (packed
//! values + O(1) seed overhead per layer — no index memory; the i8 tier
//! cuts the values ~4x on top).

use lfsr_prune::hw::layers::vgg16_modified;
use lfsr_prune::mask::prs::PrsMaskConfig;
use lfsr_prune::mask::{magnitude_mask, prune_target, random_mask};
use lfsr_prune::serve::{synthetic_lenet300, CompiledLayer, CompiledModel, InferenceSession};
use lfsr_prune::sparse::Precision;
use lfsr_prune::store::format::{
    file_overhead_bytes, fnv1a64, prs_record_bytes, PRS_EXTRA_BYTES, RECORD_FIXED_BYTES,
};
use lfsr_prune::store::{
    decode_model, encode_model, encode_with_report, export_model, load_model, verify_file,
    LoadOptions, StoreError,
};

use lfsr_prune::data::rng::Pcg32;

const D0: usize = 48;
const D1: usize = 32;
const D2: usize = 10;

fn weights(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Pcg32::new(seed);
    (0..n).map(|_| rng.next_normal()).collect()
}

/// Two-layer model with one mask method applied to both layers (same
/// construction as `serve_integration.rs`).
fn model_for(method: &str, shards: usize) -> CompiledModel {
    let w1 = weights(D0 * D1, 10);
    let w2 = weights(D1 * D2, 11);
    let b1 = weights(D1, 12);
    let b2 = weights(D2, 13);
    let layer = |w: &[f32], b: Vec<f32>, relu: bool, rows: usize, cols: usize, salt: u32| {
        match method {
            "prs" => {
                let cfg = PrsMaskConfig::auto(rows, cols, 3 + salt, 7 + salt);
                CompiledLayer::compile_prs(w, b, relu, rows, cols, 0.8, cfg, shards, 2)
            }
            "magnitude" => {
                let m = magnitude_mask(rows, cols, w, 0.8);
                CompiledLayer::from_mask(w, b, relu, &m, shards)
            }
            "random" => {
                let m = random_mask(rows, cols, 0.8, 99 + salt as u64);
                CompiledLayer::from_mask(w, b, relu, &m, shards)
            }
            other => panic!("unknown method {other}"),
        }
    };
    CompiledModel::new(vec![
        layer(&w1, b1, true, D0, D1, 0),
        layer(&w2, b2, false, D1, D2, 1),
    ])
}

fn tmp_path(name: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("lfsrpack_test_{}_{name}", std::process::id()))
}

fn assert_bitwise_eq(a: &[f32], b: &[f32], ctx: &str) {
    assert_eq!(a.len(), b.len(), "{ctx}: length");
    for (i, (&u, &v)) in a.iter().zip(b).enumerate() {
        assert_eq!(u.to_bits(), v.to_bits(), "{ctx}: logit {i}");
    }
}

// ---------------------------------------------------------------------------
// Round-trip parity
// ---------------------------------------------------------------------------

#[test]
fn roundtrip_bitwise_all_mask_methods_any_workers_shards() {
    let batch = 5;
    let x = weights(batch * D0, 21);
    for method in ["prs", "magnitude", "random"] {
        let original = model_for(method, 3);
        let reference = InferenceSession::new(original.clone(), 1).infer_batch(&x, batch);
        let bytes = encode_model(&original, 2).expect("encode");
        for n_shards in [1usize, 3, 7] {
            for workers in [1usize, 4] {
                let opts = LoadOptions { n_shards, lanes: 2, verify: true, precision: None };
                let loaded = decode_model(&bytes, &opts).expect("decode");
                let got = InferenceSession::new(loaded, workers).infer_batch(&x, batch);
                assert_bitwise_eq(
                    &got,
                    &reference,
                    &format!("{method} shards={n_shards} workers={workers}"),
                );
            }
        }
    }
}

#[test]
fn synthetic_lenet300_export_load_parity() {
    // The acceptance case: inference through an exported-then-loaded
    // artifact equals inference through CompiledModel::compile_prs
    // bit-for-bit, for any worker/shard count.
    let original = synthetic_lenet300(0.9, 4, 2);
    let batch = 3;
    let x = weights(batch * 784, 31);
    let reference = InferenceSession::new(original.clone(), 1).infer_batch(&x, batch);
    let path = tmp_path("lenet300");
    let report = export_model(&original, &path, 2).expect("export");
    assert_eq!(report.layers, 3);
    for (n_shards, workers) in [(1usize, 1usize), (5, 3), (16, 2)] {
        let opts = LoadOptions { n_shards, lanes: 2, verify: false, precision: None };
        let loaded = load_model(&path, &opts).expect("load");
        assert_eq!(loaded.nnz(), original.nnz());
        let got = InferenceSession::new(loaded, workers).infer_batch(&x, batch);
        assert_bitwise_eq(&got, &reference, &format!("shards={n_shards} workers={workers}"));
    }
    let v = verify_file(&path, 2).expect("verify");
    assert_eq!(v.prs_layers_verified, 3);
    let _ = std::fs::remove_file(&path);
}

// ---------------------------------------------------------------------------
// Corruption robustness: typed errors, never panics
// ---------------------------------------------------------------------------

fn opts() -> LoadOptions {
    LoadOptions { n_shards: 2, lanes: 1, verify: false, precision: None }
}

#[test]
fn flipped_byte_anywhere_is_a_checksum_error() {
    let bytes = encode_model(&model_for("prs", 2), 1).expect("encode");
    // Flip one byte in the value payload and one in a record header.
    for at in [bytes.len() / 2, 30] {
        let mut bad = bytes.clone();
        bad[at] ^= 0x40;
        match decode_model(&bad, &opts()) {
            Err(StoreError::ChecksumMismatch { .. }) => {}
            other => panic!("byte {at}: expected ChecksumMismatch, got {other:?}"),
        }
    }
}

#[test]
fn truncated_file_is_a_truncation_error() {
    let bytes = encode_model(&model_for("random", 2), 1).expect("encode");
    for keep in [0, 10, 23, bytes.len() / 2, bytes.len() - 1] {
        match decode_model(&bytes[..keep], &opts()) {
            Err(StoreError::Truncated { got, .. }) => assert_eq!(got, keep as u64),
            other => panic!("keep {keep}: expected Truncated, got {other:?}"),
        }
    }
}

#[test]
fn wrong_version_and_magic_are_typed_errors() {
    let bytes = encode_model(&model_for("magnitude", 1), 1).expect("encode");
    let mut wrong_version = bytes.clone();
    wrong_version[8] = 99; // version field, checked before the checksum
    match decode_model(&wrong_version, &opts()) {
        Err(StoreError::UnsupportedVersion { found: 99 }) => {}
        other => panic!("expected UnsupportedVersion, got {other:?}"),
    }
    let mut wrong_magic = bytes;
    wrong_magic[0] = b'X';
    assert!(matches!(decode_model(&wrong_magic, &opts()), Err(StoreError::BadMagic)));
    assert!(matches!(
        decode_model(b"LFSRPACK", &opts()),
        Err(StoreError::Truncated { .. })
    ));
}

/// Patch `bytes[at..at+len]`, then re-stamp the trailing checksum so the
/// corruption survives the checksum gate and must be caught by field
/// validation.
fn patch_and_restamp(bytes: &[u8], at: usize, patch: &[u8]) -> Vec<u8> {
    let mut out = bytes.to_vec();
    out[at..at + patch.len()].copy_from_slice(patch);
    let end = out.len() - 8;
    let crc = fnv1a64(&out[..end]);
    out[end..].copy_from_slice(&crc.to_le_bytes());
    out
}

#[test]
fn crafted_fields_are_corrupt_errors_not_panics() {
    let bytes = encode_model(&model_for("prs", 2), 1).expect("encode");
    let record0 = (8 + 4 + 4 + 8) as usize; // first byte of layer 0
    // Unknown mask kind tag.
    match decode_model(&patch_and_restamp(&bytes, record0, &[7]), &opts()) {
        Err(StoreError::Corrupt { detail }) => assert!(detail.contains("kind"), "{detail}"),
        other => panic!("expected Corrupt, got {other:?}"),
    }
    // Unknown flags.
    match decode_model(&patch_and_restamp(&bytes, record0 + 1, &[0xFF]), &opts()) {
        Err(StoreError::Corrupt { .. }) => {}
        other => panic!("expected Corrupt, got {other:?}"),
    }
    // Zero rows.
    match decode_model(&patch_and_restamp(&bytes, record0 + 2, &0u32.to_le_bytes()), &opts()) {
        Err(StoreError::Corrupt { .. }) => {}
        other => panic!("expected Corrupt, got {other:?}"),
    }
    // nnz inflated beyond rows*cols.
    let nnz_at = record0 + 10;
    match decode_model(
        &patch_and_restamp(&bytes, nnz_at, &u64::MAX.to_le_bytes()),
        &opts(),
    ) {
        Err(StoreError::Corrupt { .. }) => {}
        other => panic!("expected Corrupt, got {other:?}"),
    }
    // Row LFSR width changed out from under its stored polynomial.
    let widths_at = record0 + RECORD_FIXED_BYTES as usize;
    match decode_model(&patch_and_restamp(&bytes, widths_at, &[2]), &opts()) {
        Err(StoreError::Corrupt { .. }) => {}
        other => panic!("expected Corrupt, got {other:?}"),
    }
    // Layer count of zero.
    match decode_model(&patch_and_restamp(&bytes, 12, &0u32.to_le_bytes()), &opts()) {
        Err(StoreError::Corrupt { .. }) => {}
        other => panic!("expected Corrupt, got {other:?}"),
    }
}

#[test]
fn verify_catches_reseeded_artifact() {
    let bytes = encode_model(&model_for("prs", 2), 1).expect("encode");
    // seed_row of layer 0 sits after the fixed record part, widths, and
    // polynomials.
    let seed_at = (8 + 4 + 4 + 8) + RECORD_FIXED_BYTES as usize + 2 + 8;
    let orig_seed = u32::from_le_bytes(bytes[seed_at..seed_at + 4].try_into().unwrap());
    let reseeded = patch_and_restamp(&bytes, seed_at, &(orig_seed + 1).to_le_bytes());
    // Without verify the file is structurally fine (same dims, same keep
    // budget) — it loads, silently packing values for the WRONG walk...
    let loaded = decode_model(&reseeded, &opts()).expect("structurally valid");
    assert_eq!(loaded.nnz(), model_for("prs", 2).nnz());
    // ...which is exactly what verify exists to catch: the replayed walk
    // hash no longer matches the stored packing.
    let strict = LoadOptions { n_shards: 2, lanes: 1, verify: true, precision: None };
    match decode_model(&reseeded, &strict) {
        Err(StoreError::WalkMismatch { layer: 0, .. }) => {}
        other => panic!("expected WalkMismatch, got {other:?}"),
    }
}

// ---------------------------------------------------------------------------
// Precision tiers: v2 round-trip, v1 back-compat, malformed scales
// ---------------------------------------------------------------------------

#[test]
fn quantized_roundtrip_bitwise_all_mask_methods_any_workers_shards() {
    // The v2 acceptance case: an i8-tier model encodes its raw codes +
    // scales (no dequantization round trip), so a load must reproduce
    // the exact logits of the in-memory quantized model — any shard or
    // worker count, every mask family.
    let batch = 5;
    let x = weights(batch * D0, 61);
    for method in ["prs", "magnitude", "random"] {
        let original = model_for(method, 3).to_precision(Precision::I8);
        let reference = InferenceSession::new(original.clone(), 1).infer_batch(&x, batch);
        let bytes = encode_model(&original, 2).expect("encode");
        for n_shards in [1usize, 3, 7] {
            for workers in [1usize, 4] {
                let opts = LoadOptions { n_shards, lanes: 2, verify: true, precision: None };
                let loaded = decode_model(&bytes, &opts).expect("decode");
                assert_eq!(loaded.uniform_precision(), Some(Precision::I8));
                let got = InferenceSession::new(loaded, workers).infer_batch(&x, batch);
                assert_bitwise_eq(
                    &got,
                    &reference,
                    &format!("i8 {method} shards={n_shards} workers={workers}"),
                );
            }
        }
    }
}

#[test]
fn quantized_lenet300_artifact_cuts_value_bytes_4x() {
    let f = synthetic_lenet300(0.9, 2, 1);
    let q = f.to_precision(Precision::I8);
    let (fb, fr) = encode_with_report(&f, 1).expect("f32 encode");
    let (qb, qr) = encode_with_report(&q, 1).expect("i8 encode");
    // Values shrink exactly 4x (4 B -> 1 B per kept entry); the new cost
    // is one 4 B scale per column; seeds/index state are unchanged.
    assert_eq!(fr.value_bytes, 4 * qr.value_bytes);
    let cols: u64 = q.layers.iter().map(|l| l.cols as u64).sum();
    assert_eq!(qr.scale_bytes, 4 * cols);
    assert_eq!(fr.seed_bytes, qr.seed_bytes);
    assert!(qb.len() < fb.len());
    // And a mixed-tier model (quantized trunk, f32 head) round-trips
    // with per-layer tags.
    let mut mixed = f.clone();
    mixed.layers[0] = mixed.layers[0].to_precision(Precision::I8);
    mixed.layers[1] = mixed.layers[1].to_precision(Precision::I8);
    let bytes = encode_model(&mixed, 1).expect("mixed encode");
    let loaded = decode_model(&bytes, &opts()).expect("mixed decode");
    assert_eq!(loaded.uniform_precision(), None);
    assert_eq!(loaded.layers[0].precision, Precision::I8);
    assert_eq!(loaded.layers[2].precision, Precision::F32);
}

#[test]
fn v1_artifact_still_loads_as_f32() {
    // Fixture: a v1 byte stream.  v1 and v2 have the identical record
    // layout for f32 planes (the only plane v1 had), so the canonical
    // way to produce one is to stamp version 1 over an f32 v2 encode and
    // re-checksum — the payload bytes are untouched.
    let batch = 4;
    let x = weights(batch * D0, 71);
    for method in ["prs", "magnitude"] {
        let model = model_for(method, 2);
        let v2 = encode_model(&model, 1).expect("encode");
        assert_eq!(u32::from_le_bytes(v2[8..12].try_into().unwrap()), 2, "writer is at v2");
        let v1 = patch_and_restamp(&v2, 8, &1u32.to_le_bytes());
        let strict = LoadOptions { n_shards: 3, lanes: 1, verify: true, precision: None };
        let loaded = decode_model(&v1, &strict).expect("v1 decodes");
        assert_eq!(loaded.uniform_precision(), Some(Precision::F32));
        let got = InferenceSession::new(loaded, 2).infer_batch(&x, batch);
        let reference = InferenceSession::new(model, 1).infer_batch(&x, batch);
        assert_bitwise_eq(&got, &reference, &format!("v1 {method}"));
        // A v1 load can still opt into the i8 tier at load time.
        let quantizing = LoadOptions {
            n_shards: 3,
            lanes: 1,
            verify: false,
            precision: Some(Precision::I8),
        };
        let q = decode_model(&v1, &quantizing).expect("v1 + load-time i8");
        assert_eq!(q.uniform_precision(), Some(Precision::I8));
    }
}

#[test]
fn v1_artifact_with_i8_flag_is_corrupt_not_misread() {
    // The i8 flag did not exist in v1: a v1 header claiming it is
    // corrupt (re-stamped so the checksum gate cannot catch it first).
    let q = model_for("prs", 2).to_precision(Precision::I8);
    let v2 = encode_model(&q, 1).expect("encode");
    let v1 = patch_and_restamp(&v2, 8, &1u32.to_le_bytes());
    match decode_model(&v1, &opts()) {
        Err(StoreError::Corrupt { detail }) => {
            assert!(detail.contains("v2") && detail.contains("v1"), "{detail}");
        }
        other => panic!("expected Corrupt, got {other:?}"),
    }
}

#[test]
fn version_skew_error_names_both_supported_versions() {
    // A future v3 artifact must fail with a message an operator can act
    // on: the found version AND the v1..=v2 range this build reads.
    let bytes = encode_model(&model_for("prs", 1), 1).expect("encode");
    let v3 = patch_and_restamp(&bytes, 8, &3u32.to_le_bytes());
    match decode_model(&v3, &opts()) {
        Err(e @ StoreError::UnsupportedVersion { found: 3 }) => {
            let msg = e.to_string();
            assert!(msg.contains('3'), "{msg}");
            assert!(msg.contains("v1") && msg.contains("v2"), "{msg}");
        }
        other => panic!("expected UnsupportedVersion, got {other:?}"),
    }
}

#[test]
fn malformed_scales_are_typed_errors() {
    // Checksum-valid bytes whose scale vector is poison (NaN / -1 / inf)
    // must come back as BadScale naming layer and column — never load.
    let q = model_for("prs", 2).to_precision(Precision::I8);
    let bytes = encode_model(&q, 1).expect("encode");
    // Layer 0 scale vector starts after the fixed record, PRS extras,
    // and the bias payload (D1 f32s).
    let record0 = (8 + 4 + 4 + 8) as usize;
    let scales_at = record0 + (RECORD_FIXED_BYTES + PRS_EXTRA_BYTES) as usize + 4 * D1;
    for (bad, name) in [
        (f32::NAN, "NaN"),
        (f32::NEG_INFINITY, "-inf"),
        (-1.0f32, "negative"),
    ] {
        let patched = patch_and_restamp(&bytes, scales_at + 4 * 2, &bad.to_le_bytes());
        match decode_model(&patched, &opts()) {
            Err(StoreError::BadScale { layer: 0, column: 2, value }) => {
                assert!(value.is_nan() || value < 0.0, "{name}: value {value}");
            }
            other => panic!("{name}: expected BadScale, got {other:?}"),
        }
    }
    // Zero is legal (all-zero column) — the untouched artifact loads.
    decode_model(&bytes, &opts()).expect("clean quantized artifact loads");
}

// ---------------------------------------------------------------------------
// The paper's artifact-size claim
// ---------------------------------------------------------------------------

#[test]
fn exported_file_size_matches_size_model_exactly() {
    let model = model_for("prs", 2);
    let (bytes, report) = encode_with_report(&model, 1).expect("encode");
    let predicted: u64 = file_overhead_bytes()
        + model
            .layers
            .iter()
            .map(|l| prs_record_bytes(l.nnz() as u64, l.bias.len() as u64))
            .sum::<u64>();
    assert_eq!(bytes.len() as u64, predicted);
    assert_eq!(report.total_bytes, predicted);
}

#[test]
fn vgg16_artifact_overhead_is_seeds_only() {
    // Modified VGG-16 FC layers at the paper's ~10x compression rate
    // (90% sparsity): on-disk index overhead must be O(layers) seed
    // bytes, with the payload exactly the packed non-zero values.
    let net = vgg16_modified();
    let sp = 0.9;
    let value_bytes = net.fc_param_bytes(sp);
    assert!(value_bytes > 8_000_000, "VGG FC values should be MBs: {value_bytes}");
    let artifact_bytes: u64 = file_overhead_bytes()
        + net
            .layers
            .iter()
            .map(|d| {
                let kept = (d.size() - prune_target(d.rows, d.cols, sp)) as u64;
                prs_record_bytes(kept, 0)
            })
            .sum::<u64>();
    let overhead = artifact_bytes - value_bytes;
    let per_layer = RECORD_FIXED_BYTES + PRS_EXTRA_BYTES;
    assert_eq!(overhead, file_overhead_bytes() + net.layers.len() as u64 * per_layer);
    // O(1) per layer, O(1) per file: under 64 B each, ~200 B total for a
    // 9.2 MB payload — versus O(nnz) index entries for a CSC artifact
    // (2.29M 13-bit indices ≈ 3.7 MB at this rate).
    assert!(per_layer < 64, "per-layer overhead {per_layer}");
    assert!(overhead < 256, "total index+framing overhead {overhead}");
    assert!((overhead as f64) < 1e-4 * value_bytes as f64);
}
