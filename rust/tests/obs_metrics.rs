//! Integration pins for the obs metrics core: the histogram's quantile
//! math against the python executable mirror
//! (`python/tests/test_obs_pins.py` — same Pcg32 stream, same pinned
//! constants, bit-identical f64 expression), exact accounting under
//! thread contention, and the text-exposition line grammar CI's smoke
//! step parses.

use std::sync::Arc;

use lfsr_prune::data::rng::Pcg32;
use lfsr_prune::obs::{labels, Counter, Histogram, MetricsRegistry, HIST_BUCKETS};

/// Shared fixture with the python mirror: 100k samples
/// `1 + (next_u32() % 50_000_000)` ns from `Pcg32::new(0xB5)`.
const SEED: u64 = 0xB5;
const N_SAMPLES: usize = 100_000;
const MODULUS: u32 = 50_000_000;

/// Pins derived by `python3 python/tests/test_obs_pins.py`; the python
/// suite asserts the identical values.
const PIN_COUNT: u64 = 100_000;
const PIN_SUM_NS: u64 = 2_508_770_600_668;
const PIN_MIN_NS: u64 = 14;
const PIN_MAX_NS: u64 = 49_999_712;
const PIN_P50_NS: f64 = 25_139_218.995870985;
// p95/p99 interpolate past the observed ceiling inside the top occupied
// bucket, so the [min, max] clamp snaps both to the exact max.
const PIN_P95_NS: f64 = 49_999_712.0;
const PIN_P99_NS: f64 = 49_999_712.0;
// Exact rank statistics (sorted sample at rank ceil(q*n)) of the same
// stream, so the 2x error bound is checked against ground truth.
const PIN_EXACT_P50_NS: u64 = 25_126_468;
const PIN_EXACT_P95_NS: u64 = 47_505_180;
const PIN_EXACT_P99_NS: u64 = 49_503_444;

fn sample_stream() -> Vec<u64> {
    let mut rng = Pcg32::new(SEED);
    (0..N_SAMPLES).map(|_| 1 + (rng.next_u32() % MODULUS) as u64).collect()
}

fn exact_quantile(sorted_ns: &[u64], q: f64) -> u64 {
    let n = sorted_ns.len() as u64;
    let target = ((q * n as f64).ceil() as u64).clamp(1, n);
    sorted_ns[target as usize - 1]
}

#[test]
fn quantiles_match_python_mirror_pins() {
    let h = Histogram::new();
    let mut ns = sample_stream();
    for &v in &ns {
        h.record_ns(v);
    }
    assert_eq!(h.count(), PIN_COUNT);
    assert_eq!(h.sum_ns(), PIN_SUM_NS);
    assert_eq!(h.min_ns(), Some(PIN_MIN_NS));
    assert_eq!(h.max_ns(), Some(PIN_MAX_NS));

    // The estimate formula is the same IEEE f64 expression on both
    // sides, so the pins match to well below 1e-9 relative.
    for (q, pin) in [(0.5, PIN_P50_NS), (0.95, PIN_P95_NS), (0.99, PIN_P99_NS)] {
        let est = h.quantile_ns(q).unwrap();
        assert!((est - pin).abs() <= pin * 1e-9, "q={q}: est {est} vs pinned {pin}");
    }

    // Ground truth: estimates stay within the documented 2x bound of
    // the exact rank statistic (and the exact ranks themselves are
    // pinned, shared with the python suite).
    ns.sort_unstable();
    for (q, exact_pin) in [
        (0.5, PIN_EXACT_P50_NS),
        (0.95, PIN_EXACT_P95_NS),
        (0.99, PIN_EXACT_P99_NS),
    ] {
        let exact = exact_quantile(&ns, q);
        assert_eq!(exact, exact_pin, "q={q}");
        let ratio = h.quantile_ns(q).unwrap() / exact as f64;
        assert!((0.5..=2.0).contains(&ratio), "q={q}: ratio {ratio}");
    }
}

#[test]
fn concurrent_records_are_exact() {
    // N threads x M records: counts and sums are exact (relaxed atomics
    // lose ordering, never increments), min/max are exact extremes.
    const THREADS: u64 = 8;
    const PER_THREAD: u64 = 125_000;
    let h = Arc::new(Histogram::new());
    let c = Arc::new(Counter::new());
    let handles: Vec<_> = (0..THREADS)
        .map(|t| {
            let h = Arc::clone(&h);
            let c = Arc::clone(&c);
            std::thread::spawn(move || {
                for i in 0..PER_THREAD {
                    h.record_ns(1 + t * PER_THREAD + i);
                    c.inc();
                }
            })
        })
        .collect();
    for handle in handles {
        handle.join().unwrap();
    }
    let total = THREADS * PER_THREAD;
    assert_eq!(h.count(), total);
    assert_eq!(c.get(), total);
    // Sum of 1 + k for k in 0..total.
    assert_eq!(h.sum_ns(), total + total * (total - 1) / 2);
    assert_eq!(h.min_ns(), Some(1));
    assert_eq!(h.max_ns(), Some(total));
    let buckets = h.bucket_counts();
    assert_eq!(buckets.iter().sum::<u64>(), total);
    assert_eq!(buckets.len(), HIST_BUCKETS);
}

#[test]
fn render_text_lines_parse_as_exposition_grammar() {
    // Same grammar the CI smoke step enforces: every non-comment line is
    // `name value` or `name{k="v",...} value` with a finite f64 value.
    let reg = MetricsRegistry::new();
    reg.counter("serve_requests_total", labels(&[("model", "m0")])).add(7);
    reg.gauge("serve_queue_depth", labels(&[("model", "m0")])).set(3);
    let h = reg.histogram("serve_stage_seconds", labels(&[("model", "m0"), ("stage", "cut")]));
    for v in [800u64, 1_500, 65_000, 2_000_000] {
        h.record_ns(v);
    }
    let text = reg.render_text();
    let mut parsed = 0usize;
    for line in text.lines() {
        if line.starts_with('#') {
            assert!(
                line.starts_with("# TYPE "),
                "only TYPE comments are emitted: {line}"
            );
            continue;
        }
        let (series, value) = line.rsplit_once(' ').expect("line has a value field");
        let name = series.split('{').next().unwrap();
        assert!(!name.is_empty(), "line has a metric name: {line}");
        assert!(
            name.chars().all(|ch| ch.is_ascii_alphanumeric() || ch == '_'),
            "metric name is [a-zA-Z0-9_]: {name}"
        );
        let rest = &series[name.len()..];
        if !rest.is_empty() {
            assert!(rest.starts_with('{') && rest.ends_with('}'), "label block: {rest}");
        }
        let v: f64 = value.parse().expect("value parses as f64");
        assert!(v.is_finite(), "finite value: {line}");
        parsed += 1;
    }
    assert!(parsed >= 10, "counter + gauge + expanded histogram series: {text}");
    for required in [
        "serve_requests_total{model=\"m0\"} 7",
        "serve_queue_depth{model=\"m0\"} 3",
        "serve_stage_seconds_count{model=\"m0\",stage=\"cut\"} 4",
    ] {
        assert!(text.contains(required), "missing `{required}` in:\n{text}");
    }
}
