//! Property-based invariant tests over the coordinator substrates.
//!
//! proptest is not in the offline vendor set, so this uses the same
//! pattern with an in-repo harness: seeded PCG32 case generation, many
//! cases per property, and the failing case's parameters printed via the
//! assert message (substitute shrinking with deterministic replay — every
//! case is reproducible from its printed seed).

use lfsr_prune::data::rng::Pcg32;
use lfsr_prune::data::{synth, Batcher, SynthSpec};
use lfsr_prune::hw::{baseline, lfsr_engine, Mode, SparseLayer};
use lfsr_prune::lfsr::{period, GaloisLfsr, JumpTable, MsbMap};
use lfsr_prune::mask::prs::{prs_keep_sequence, prs_mask, PrsMaskConfig};
use lfsr_prune::mask::{magnitude_mask, prune_target, random_mask, Mask};
use lfsr_prune::serve::{CompiledLayer, CompiledModel, InferenceSession};
use lfsr_prune::sparse::{col2im_into, im2col_into, ConvGeom, CscMatrix, Precision};
use lfsr_prune::util::json;

const CASES: usize = 60;

fn gen_dims(rng: &mut Pcg32) -> (usize, usize) {
    (
        4 + rng.next_below(200) as usize,
        4 + rng.next_below(200) as usize,
    )
}

fn gen_sparsity(rng: &mut Pcg32) -> f64 {
    (rng.next_below(96) as f64 + 1.0) / 100.0
}

#[test]
fn prop_prs_mask_exact_sparsity_and_determinism() {
    let mut rng = Pcg32::new(0xDEAD);
    for case in 0..CASES {
        let (r, c) = gen_dims(&mut rng);
        let sp = gen_sparsity(&mut rng);
        let cfg = PrsMaskConfig::auto(r, c, rng.next_u32(), rng.next_u32());
        let m1 = prs_mask(r, c, sp, cfg);
        let m2 = prs_mask(r, c, sp, cfg);
        assert_eq!(m1, m2, "case {case}: nondeterministic ({r}x{c} sp={sp})");
        assert_eq!(
            r * c - m1.nnz(),
            prune_target(r, c, sp),
            "case {case}: wrong sparsity ({r}x{c} sp={sp} cfg={cfg:?})"
        );
    }
}

#[test]
fn prop_keep_sequence_is_prefix_consistent() {
    // Walk order must be stable under sparsity: the kept positions at a
    // HIGHER sparsity (fewer kept) are exactly a prefix of the walk at a
    // lower sparsity.  This is what lets one set of seeds serve several
    // operating points and keeps the weight-memory layout append-only.
    let mut rng = Pcg32::new(0xBEE);
    for case in 0..20 {
        let (r, c) = gen_dims(&mut rng);
        let cfg = PrsMaskConfig::auto(r, c, rng.next_u32(), rng.next_u32());
        let hi = prs_keep_sequence(r, c, 0.9, cfg); // few kept
        let lo = prs_keep_sequence(r, c, 0.5, cfg); // more kept
        assert!(
            hi.len() <= lo.len(),
            "case {case}: prefix sizes inverted ({r}x{c})"
        );
        assert_eq!(
            hi[..],
            lo[..hi.len()],
            "case {case}: walk not prefix-consistent ({r}x{c} cfg={cfg:?})"
        );
    }
}

#[test]
fn prop_csc_roundtrip_any_mask_any_bits() {
    let mut rng = Pcg32::new(0xC5C);
    for case in 0..CASES {
        let (r, c) = gen_dims(&mut rng);
        let sp = gen_sparsity(&mut rng);
        let bits = if rng.next_below(2) == 0 { 4 } else { 8 };
        let mask = random_mask(r, c, sp, rng.next_u32() as u64);
        let mut w: Vec<f32> = (0..r * c).map(|_| rng.next_normal()).collect();
        mask.apply_to(&mut w);
        let csc = CscMatrix::encode(&w, &mask, bits, 8);
        assert_eq!(csc.decode(), w, "case {case}: roundtrip ({r}x{c} sp={sp} {bits}b)");
        assert_eq!(csc.nnz, mask.nnz(), "case {case}: nnz mismatch");
        assert!(csc.alpha() >= 1.0, "case {case}: alpha < 1");
    }
}

#[test]
fn prop_engines_compute_identical_matvec() {
    // Coordinator invariant: both datapaths and the dense reference agree
    // for any PRS mask — the heart of the hardware claim.
    let mut rng = Pcg32::new(0xE46);
    for case in 0..25 {
        let (r, c) = gen_dims(&mut rng);
        let sp = gen_sparsity(&mut rng).max(0.2);
        let cfg = PrsMaskConfig::auto(r, c, rng.next_u32(), rng.next_u32());
        let mask = prs_mask(r, c, sp, cfg);
        let layer = SparseLayer {
            rows: r,
            cols: c,
            weights: (0..r * c).map(|_| rng.next_normal()).collect(),
            mask,
            input: (0..r).map(|_| rng.next_normal()).collect(),
        };
        let reference = layer.reference_output();
        let bits = if rng.next_below(2) == 0 { 4 } else { 8 };
        let b = baseline::run(&layer, bits, 8);
        let p = lfsr_engine::run(&layer, cfg, Mode::Ideal);
        for i in 0..c {
            assert!(
                (b.output[i] - reference[i]).abs() < 1e-2,
                "case {case}: baseline diverges at {i} ({r}x{c} sp={sp})"
            );
            assert!(
                (p.output[i] - reference[i]).abs() < 1e-2,
                "case {case}: lfsr engine diverges at {i} ({r}x{c} sp={sp})"
            );
        }
        assert_eq!(b.counters.mac_ops, p.counters.mac_ops, "case {case}");
    }
}

#[test]
fn prop_magnitude_mask_keeps_largest() {
    let mut rng = Pcg32::new(0x3A6);
    for case in 0..CASES {
        let (r, c) = gen_dims(&mut rng);
        let sp = gen_sparsity(&mut rng);
        let w: Vec<f32> = (0..r * c).map(|_| rng.next_normal()).collect();
        let m = magnitude_mask(r, c, &w, sp);
        let mut kept_min = f32::INFINITY;
        let mut pruned_max = 0f32;
        for (i, &k) in m.keep_bytes().iter().enumerate() {
            if k == 1 {
                kept_min = kept_min.min(w[i].abs());
            } else {
                pruned_max = pruned_max.max(w[i].abs());
            }
        }
        if m.nnz() > 0 && m.nnz() < r * c {
            assert!(
                kept_min >= pruned_max - 1e-6,
                "case {case}: kept {kept_min} < pruned {pruned_max} ({r}x{c} sp={sp})"
            );
        }
    }
}

#[test]
fn prop_jump_table_equals_serial_any_offset() {
    let mut rng = Pcg32::new(0x10F);
    for _ in 0..10 {
        let n = 6 + rng.next_below(12);
        let jt = JumpTable::new(n, 24);
        let seed = 1 + rng.next_below((period(n) as u32).min(1 << 20));
        let mut l = GaloisLfsr::new(n, seed);
        let serial: Vec<u32> = (0..512).map(|_| l.next_state()).collect();
        for _ in 0..24 {
            let t = 1 + rng.next_below(512) as u64;
            assert_eq!(
                jt.state_at(seed, t),
                serial[(t - 1) as usize],
                "n={n} seed={seed} t={t}"
            );
        }
    }
}

#[test]
fn prop_msb_map_in_range_and_covers() {
    let mut rng = Pcg32::new(0xAB1);
    for case in 0..30 {
        let domain = 2 + rng.next_below(1000) as usize;
        let n = lfsr_prune::lfsr::width_for_domain(domain);
        let mut m = MsbMap::new(GaloisLfsr::new(n, 1 + rng.next_u32() % 1000), domain);
        let mut seen = vec![false; domain];
        let draws = (domain * 40).min(400_000);
        for _ in 0..draws {
            let i = m.next_index();
            assert!(i < domain, "case {case}: out of range");
            seen[i] = true;
        }
        let covered = seen.iter().filter(|&&s| s).count();
        assert!(
            covered as f64 > domain as f64 * 0.95,
            "case {case}: covered only {covered}/{domain}"
        );
    }
}

#[test]
fn prop_batcher_visits_every_example_each_epoch() {
    let mut rng = Pcg32::new(0xBA7);
    for case in 0..20 {
        let n = 10 + rng.next_below(200) as usize;
        let batch = 1 + rng.next_below(n as u32) as usize;
        let data = synth::generate(&SynthSpec::mnist_like(case as u64), n);
        let mut b = Batcher::new(&data, batch, case as u64);
        let full_batches = n / batch;
        let mut seen = std::collections::HashSet::new();
        for _ in 0..full_batches {
            let bt = b.next_batch();
            for ex in bt.x.chunks(data.example_len()) {
                // Pixel sum is unique per example w.h.p. (clamping makes
                // single pixels collide at 0.0/1.0, so hash the whole
                // example instead).
                let key: f64 = ex.iter().map(|&v| v as f64).sum();
                seen.insert(key.to_bits());
            }
        }
        assert!(
            seen.len() as f64 >= (full_batches * batch) as f64 * 0.98,
            "case {case}: repeats within epoch (n={n} batch={batch})"
        );
    }
}

#[test]
fn prop_rank_bounded_and_mask_monotone() {
    let mut rng = Pcg32::new(0x4A4);
    for case in 0..15 {
        let (r, c) = (10 + rng.next_below(60) as usize, 10 + rng.next_below(60) as usize);
        let w: Vec<f32> = (0..r * c).map(|_| rng.next_normal()).collect();
        let full = matrix_rank(r, c, &w);
        assert!(full <= r.min(c), "case {case}");
        let cfg = PrsMaskConfig::auto(r, c, rng.next_u32(), rng.next_u32());
        let mask = prs_mask(r, c, 0.5, cfg);
        let mut wm = w.clone();
        mask.apply_to(&mut wm);
        let masked = matrix_rank(r, c, &wm);
        assert!(masked <= full, "case {case}: masking raised rank?");
    }
}

// ---------------------------------------------------------------------------
// Conv geometry properties (the im2col lowering behind LayerShape::Conv)
// ---------------------------------------------------------------------------

/// Random small-but-varied conv geometry: kernel 1..=3, stride 1..=3,
/// pad < kernel, dims sized so batch 33 stays cheap.
fn gen_conv_geom(rng: &mut Pcg32) -> ConvGeom {
    let kernel = 1 + rng.next_below(3) as usize;
    let stride = 1 + rng.next_below(3) as usize;
    let pad = rng.next_below(kernel as u32) as usize;
    ConvGeom {
        in_h: kernel + rng.next_below(6) as usize,
        in_w: kernel + rng.next_below(6) as usize,
        in_c: 1 + rng.next_below(3) as usize,
        out_c: 1 + rng.next_below(5) as usize,
        kernel,
        stride,
        pad,
    }
}

#[test]
fn prop_conv_output_dims_match_window_count() {
    // The closed-form out_h/out_w must equal the number of kernel
    // placements counted by brute force over the padded input.
    let mut rng = Pcg32::new(0xC09);
    for case in 0..CASES {
        let g = gen_conv_geom(&mut rng);
        g.validate().unwrap_or_else(|e| panic!("case {case}: generator invalid: {e}"));
        let count = |len: usize| {
            let padded = len + 2 * g.pad;
            let mut n = 0usize;
            let mut start = 0usize;
            while start + g.kernel <= padded {
                n += 1;
                start += g.stride;
            }
            n
        };
        assert_eq!(g.out_h(), count(g.in_h), "case {case}: {g:?}");
        assert_eq!(g.out_w(), count(g.in_w), "case {case}: {g:?}");
        assert_eq!(g.out_len(), g.out_h() * g.out_w() * g.out_c, "case {case}");
        assert_eq!(g.patch_len(), g.kernel * g.kernel * g.in_c, "case {case}");
    }
}

#[test]
fn prop_im2col_col2im_identity() {
    let mut rng = Pcg32::new(0xC01);
    // Non-overlapping full tilings (stride == kernel, pad 0, dims are
    // multiples of the kernel): col2im ∘ im2col is the exact identity.
    for case in 0..20 {
        let k = 1 + rng.next_below(3) as usize;
        let g = ConvGeom {
            in_h: k * (1 + rng.next_below(4) as usize),
            in_w: k * (1 + rng.next_below(4) as usize),
            in_c: 1 + rng.next_below(3) as usize,
            out_c: 1,
            kernel: k,
            stride: k,
            pad: 0,
        };
        let batch = 1 + rng.next_below(3) as usize;
        let x: Vec<f32> = (0..batch * g.in_len()).map(|_| rng.next_normal()).collect();
        let (mut cols, mut back) = (Vec::new(), Vec::new());
        im2col_into(&x, batch, &g, &mut cols);
        col2im_into(&cols, batch, &g, &mut back);
        for (i, (&a, &b)) in back.iter().zip(&x).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "case {case} pixel {i} ({g:?})");
        }
    }
    // General geometries: col2im(im2col(x)) = x ⊙ coverage, coverage read
    // off the all-ones image (and every pixel of a valid geometry is
    // covered at least once).
    for case in 0..20 {
        let g = gen_conv_geom(&mut rng);
        let batch = 1 + rng.next_below(2) as usize;
        let x: Vec<f32> = (0..batch * g.in_len()).map(|_| rng.next_normal()).collect();
        let ones = vec![1.0f32; batch * g.in_len()];
        let (mut cols, mut cover, mut got) = (Vec::new(), Vec::new(), Vec::new());
        im2col_into(&ones, batch, &g, &mut cols);
        col2im_into(&cols, batch, &g, &mut cover);
        im2col_into(&x, batch, &g, &mut cols);
        col2im_into(&cols, batch, &g, &mut got);
        for i in 0..x.len() {
            // A stride larger than the kernel legitimately skips pixels.
            if g.stride <= g.kernel {
                assert!(cover[i] >= 1.0, "case {case} pixel {i} uncovered ({g:?})");
            }
            assert!(
                (got[i] - x[i] * cover[i]).abs()
                    <= 1e-5 * (1.0 + (x[i] * cover[i]).abs()),
                "case {case} pixel {i}: {} vs {} * {} ({g:?})",
                got[i],
                x[i],
                cover[i]
            );
        }
    }
}

#[test]
fn prop_panel_conv_bitwise_equals_scalar_conv_all_compositions() {
    // The conv acceptance matrix: the serving path (im2col panels + the
    // blocked kernel, any shard count, any worker count, any batch
    // composition) is bit-for-bit the scalar reference (im2col rows +
    // gemm_into), in EVERY precision tier — conv layers inherit the
    // sub-8-bit planes through the same im2col lowering.
    let mut rng = Pcg32::new(0xC0F);
    for case in 0..5 {
        let g = gen_conv_geom(&mut rng);
        let dense = rng.next_below(2) == 0;
        let w: Vec<f32> =
            (0..g.patch_len() * g.out_c).map(|_| rng.next_normal() * 0.3).collect();
        let bias: Vec<f32> = (0..g.out_c).map(|_| rng.next_normal() * 0.1).collect();
        let build = |shards: usize| {
            if dense {
                CompiledLayer::conv_from_mask(
                    &w,
                    bias.clone(),
                    true,
                    &Mask::dense(g.patch_len(), g.out_c),
                    g,
                    shards,
                )
            } else {
                let cfg = PrsMaskConfig::auto(g.patch_len(), g.out_c, 3 + case, 7 + case);
                CompiledLayer::compile_conv_prs(
                    &w,
                    bias.clone(),
                    true,
                    g,
                    0.5,
                    cfg,
                    shards,
                    1,
                )
            }
        };
        for tier in [Precision::F32, Precision::I8, Precision::I4, Precision::Ternary] {
            for n_shards in [1usize, 3, 7] {
                let layer = build(n_shards).to_precision(tier);
                // Scalar reference per batch: materialized im2col rows
                // through the scalar kernel, shard by shard,
                // scatter-copied.
                let cases: Vec<(usize, Vec<f32>, Vec<f32>)> = [1usize, 3, 8, 33]
                    .into_iter()
                    .map(|batch| {
                        let x: Vec<f32> =
                            (0..batch * g.in_len()).map(|_| rng.next_normal()).collect();
                        let vrows = batch * g.out_h() * g.out_w();
                        let mut cols_buf = Vec::new();
                        im2col_into(&x, batch, &g, &mut cols_buf);
                        let mut expect = vec![0.0f32; vrows * g.out_c];
                        for shard in &layer.shards {
                            let mut buf = vec![0.0f32; vrows * shard.width()];
                            shard.gemm_into(&cols_buf, vrows, &bias, true, &mut buf);
                            for v in 0..vrows {
                                expect
                                    [v * g.out_c + shard.col_start..v * g.out_c + shard.col_end]
                                    .copy_from_slice(
                                        &buf[v * shard.width()..(v + 1) * shard.width()],
                                    );
                            }
                        }
                        (batch, x, expect)
                    })
                    .collect();
                for workers in [1usize, 4] {
                    let session =
                        InferenceSession::new(CompiledModel::new(vec![layer.clone()]), workers);
                    for (batch, x, expect) in &cases {
                        let got = session.infer_batch(x, *batch);
                        assert_eq!(got.len(), expect.len());
                        for (i, (&u, &v)) in got.iter().zip(expect.iter()).enumerate() {
                            assert_eq!(
                                u.to_bits(),
                                v.to_bits(),
                                "case {case} {tier} dense={dense} shards={n_shards} \
                                 batch={batch} workers={workers} out {i} ({g:?})"
                            );
                        }
                    }
                }
            }
        }
    }
}

#[test]
fn prop_json_roundtrip_numbers_and_strings() {
    // Serialize-ish: build random nested docs textually, parse, check.
    let mut rng = Pcg32::new(0x150);
    for case in 0..40 {
        let a = rng.next_u32();
        let b = (rng.next_f32() * 1e6) as f64 / 100.0;
        let s = format!("k{}", rng.next_u32() % 1000);
        let doc = format!(
            r#"{{"a": {a}, "b": {b}, "nest": {{"s": "{s}", "arr": [1, 2.5, -3e2, true, null]}}}}"#
        );
        let j = json::parse(&doc).unwrap_or_else(|e| panic!("case {case}: {e} in {doc}"));
        assert_eq!(j.get("a").unwrap().as_f64(), Some(a as f64));
        assert_eq!(j.get("b").unwrap().as_f64(), Some(b));
        let nest = j.get("nest").unwrap();
        assert_eq!(nest.get("s").unwrap().as_str(), Some(s.as_str()));
        assert_eq!(nest.get("arr").unwrap().as_arr().unwrap().len(), 5);
    }
}
