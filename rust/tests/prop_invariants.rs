//! Property-based invariant tests over the coordinator substrates.
//!
//! proptest is not in the offline vendor set, so this uses the same
//! pattern with an in-repo harness: seeded PCG32 case generation, many
//! cases per property, and the failing case's parameters printed via the
//! assert message (substitute shrinking with deterministic replay — every
//! case is reproducible from its printed seed).

use lfsr_prune::data::rng::Pcg32;
use lfsr_prune::data::{synth, Batcher, SynthSpec};
use lfsr_prune::hw::{baseline, lfsr_engine, Mode, SparseLayer};
use lfsr_prune::lfsr::{period, GaloisLfsr, JumpTable, MsbMap};
use lfsr_prune::mask::prs::{prs_keep_sequence, prs_mask, PrsMaskConfig};
use lfsr_prune::mask::{magnitude_mask, prune_target, random_mask};
use lfsr_prune::rank::matrix_rank;
use lfsr_prune::sparse::CscMatrix;
use lfsr_prune::util::json;

const CASES: usize = 60;

fn gen_dims(rng: &mut Pcg32) -> (usize, usize) {
    (
        4 + rng.next_below(200) as usize,
        4 + rng.next_below(200) as usize,
    )
}

fn gen_sparsity(rng: &mut Pcg32) -> f64 {
    (rng.next_below(96) as f64 + 1.0) / 100.0
}

#[test]
fn prop_prs_mask_exact_sparsity_and_determinism() {
    let mut rng = Pcg32::new(0xDEAD);
    for case in 0..CASES {
        let (r, c) = gen_dims(&mut rng);
        let sp = gen_sparsity(&mut rng);
        let cfg = PrsMaskConfig::auto(r, c, rng.next_u32(), rng.next_u32());
        let m1 = prs_mask(r, c, sp, cfg);
        let m2 = prs_mask(r, c, sp, cfg);
        assert_eq!(m1, m2, "case {case}: nondeterministic ({r}x{c} sp={sp})");
        assert_eq!(
            r * c - m1.nnz(),
            prune_target(r, c, sp),
            "case {case}: wrong sparsity ({r}x{c} sp={sp} cfg={cfg:?})"
        );
    }
}

#[test]
fn prop_keep_sequence_is_prefix_consistent() {
    // Walk order must be stable under sparsity: the kept positions at a
    // HIGHER sparsity (fewer kept) are exactly a prefix of the walk at a
    // lower sparsity.  This is what lets one set of seeds serve several
    // operating points and keeps the weight-memory layout append-only.
    let mut rng = Pcg32::new(0xBEE);
    for case in 0..20 {
        let (r, c) = gen_dims(&mut rng);
        let cfg = PrsMaskConfig::auto(r, c, rng.next_u32(), rng.next_u32());
        let hi = prs_keep_sequence(r, c, 0.9, cfg); // few kept
        let lo = prs_keep_sequence(r, c, 0.5, cfg); // more kept
        assert!(
            hi.len() <= lo.len(),
            "case {case}: prefix sizes inverted ({r}x{c})"
        );
        assert_eq!(
            hi[..],
            lo[..hi.len()],
            "case {case}: walk not prefix-consistent ({r}x{c} cfg={cfg:?})"
        );
    }
}

#[test]
fn prop_csc_roundtrip_any_mask_any_bits() {
    let mut rng = Pcg32::new(0xC5C);
    for case in 0..CASES {
        let (r, c) = gen_dims(&mut rng);
        let sp = gen_sparsity(&mut rng);
        let bits = if rng.next_below(2) == 0 { 4 } else { 8 };
        let mask = random_mask(r, c, sp, rng.next_u32() as u64);
        let mut w: Vec<f32> = (0..r * c).map(|_| rng.next_normal()).collect();
        mask.apply_to(&mut w);
        let csc = CscMatrix::encode(&w, &mask, bits, 8);
        assert_eq!(csc.decode(), w, "case {case}: roundtrip ({r}x{c} sp={sp} {bits}b)");
        assert_eq!(csc.nnz, mask.nnz(), "case {case}: nnz mismatch");
        assert!(csc.alpha() >= 1.0, "case {case}: alpha < 1");
    }
}

#[test]
fn prop_engines_compute_identical_matvec() {
    // Coordinator invariant: both datapaths and the dense reference agree
    // for any PRS mask — the heart of the hardware claim.
    let mut rng = Pcg32::new(0xE46);
    for case in 0..25 {
        let (r, c) = gen_dims(&mut rng);
        let sp = gen_sparsity(&mut rng).max(0.2);
        let cfg = PrsMaskConfig::auto(r, c, rng.next_u32(), rng.next_u32());
        let mask = prs_mask(r, c, sp, cfg);
        let layer = SparseLayer {
            rows: r,
            cols: c,
            weights: (0..r * c).map(|_| rng.next_normal()).collect(),
            mask,
            input: (0..r).map(|_| rng.next_normal()).collect(),
        };
        let reference = layer.reference_output();
        let bits = if rng.next_below(2) == 0 { 4 } else { 8 };
        let b = baseline::run(&layer, bits, 8);
        let p = lfsr_engine::run(&layer, cfg, Mode::Ideal);
        for i in 0..c {
            assert!(
                (b.output[i] - reference[i]).abs() < 1e-2,
                "case {case}: baseline diverges at {i} ({r}x{c} sp={sp})"
            );
            assert!(
                (p.output[i] - reference[i]).abs() < 1e-2,
                "case {case}: lfsr engine diverges at {i} ({r}x{c} sp={sp})"
            );
        }
        assert_eq!(b.counters.mac_ops, p.counters.mac_ops, "case {case}");
    }
}

#[test]
fn prop_magnitude_mask_keeps_largest() {
    let mut rng = Pcg32::new(0x3A6);
    for case in 0..CASES {
        let (r, c) = gen_dims(&mut rng);
        let sp = gen_sparsity(&mut rng);
        let w: Vec<f32> = (0..r * c).map(|_| rng.next_normal()).collect();
        let m = magnitude_mask(r, c, &w, sp);
        let mut kept_min = f32::INFINITY;
        let mut pruned_max = 0f32;
        for (i, &k) in m.keep_bytes().iter().enumerate() {
            if k == 1 {
                kept_min = kept_min.min(w[i].abs());
            } else {
                pruned_max = pruned_max.max(w[i].abs());
            }
        }
        if m.nnz() > 0 && m.nnz() < r * c {
            assert!(
                kept_min >= pruned_max - 1e-6,
                "case {case}: kept {kept_min} < pruned {pruned_max} ({r}x{c} sp={sp})"
            );
        }
    }
}

#[test]
fn prop_jump_table_equals_serial_any_offset() {
    let mut rng = Pcg32::new(0x10F);
    for _ in 0..10 {
        let n = 6 + rng.next_below(12);
        let jt = JumpTable::new(n, 24);
        let seed = 1 + rng.next_below((period(n) as u32).min(1 << 20));
        let mut l = GaloisLfsr::new(n, seed);
        let serial: Vec<u32> = (0..512).map(|_| l.next_state()).collect();
        for _ in 0..24 {
            let t = 1 + rng.next_below(512) as u64;
            assert_eq!(
                jt.state_at(seed, t),
                serial[(t - 1) as usize],
                "n={n} seed={seed} t={t}"
            );
        }
    }
}

#[test]
fn prop_msb_map_in_range_and_covers() {
    let mut rng = Pcg32::new(0xAB1);
    for case in 0..30 {
        let domain = 2 + rng.next_below(1000) as usize;
        let n = lfsr_prune::lfsr::width_for_domain(domain);
        let mut m = MsbMap::new(GaloisLfsr::new(n, 1 + rng.next_u32() % 1000), domain);
        let mut seen = vec![false; domain];
        let draws = (domain * 40).min(400_000);
        for _ in 0..draws {
            let i = m.next_index();
            assert!(i < domain, "case {case}: out of range");
            seen[i] = true;
        }
        let covered = seen.iter().filter(|&&s| s).count();
        assert!(
            covered as f64 > domain as f64 * 0.95,
            "case {case}: covered only {covered}/{domain}"
        );
    }
}

#[test]
fn prop_batcher_visits_every_example_each_epoch() {
    let mut rng = Pcg32::new(0xBA7);
    for case in 0..20 {
        let n = 10 + rng.next_below(200) as usize;
        let batch = 1 + rng.next_below(n as u32) as usize;
        let data = synth::generate(&SynthSpec::mnist_like(case as u64), n);
        let mut b = Batcher::new(&data, batch, case as u64);
        let full_batches = n / batch;
        let mut seen = std::collections::HashSet::new();
        for _ in 0..full_batches {
            let bt = b.next_batch();
            for ex in bt.x.chunks(data.example_len()) {
                // Pixel sum is unique per example w.h.p. (clamping makes
                // single pixels collide at 0.0/1.0, so hash the whole
                // example instead).
                let key: f64 = ex.iter().map(|&v| v as f64).sum();
                seen.insert(key.to_bits());
            }
        }
        assert!(
            seen.len() as f64 >= (full_batches * batch) as f64 * 0.98,
            "case {case}: repeats within epoch (n={n} batch={batch})"
        );
    }
}

#[test]
fn prop_rank_bounded_and_mask_monotone() {
    let mut rng = Pcg32::new(0x4A4);
    for case in 0..15 {
        let (r, c) = (10 + rng.next_below(60) as usize, 10 + rng.next_below(60) as usize);
        let w: Vec<f32> = (0..r * c).map(|_| rng.next_normal()).collect();
        let full = matrix_rank(r, c, &w);
        assert!(full <= r.min(c), "case {case}");
        let cfg = PrsMaskConfig::auto(r, c, rng.next_u32(), rng.next_u32());
        let mask = prs_mask(r, c, 0.5, cfg);
        let mut wm = w.clone();
        mask.apply_to(&mut wm);
        let masked = matrix_rank(r, c, &wm);
        assert!(masked <= full, "case {case}: masking raised rank?");
    }
}

#[test]
fn prop_json_roundtrip_numbers_and_strings() {
    // Serialize-ish: build random nested docs textually, parse, check.
    let mut rng = Pcg32::new(0x150);
    for case in 0..40 {
        let a = rng.next_u32();
        let b = (rng.next_f32() * 1e6) as f64 / 100.0;
        let s = format!("k{}", rng.next_u32() % 1000);
        let doc = format!(
            r#"{{"a": {a}, "b": {b}, "nest": {{"s": "{s}", "arr": [1, 2.5, -3e2, true, null]}}}}"#
        );
        let j = json::parse(&doc).unwrap_or_else(|e| panic!("case {case}: {e} in {doc}"));
        assert_eq!(j.get("a").unwrap().as_f64(), Some(a as f64));
        assert_eq!(j.get("b").unwrap().as_f64(), Some(b));
        let nest = j.get("nest").unwrap();
        assert_eq!(nest.get("s").unwrap().as_str(), Some(s.as_str()));
        assert_eq!(nest.get("arr").unwrap().as_arr().unwrap().len(), 5);
    }
}
