//! Integration: rust runtime ⇄ AOT artifacts over PJRT.
//!
//! Requires `make artifacts` (skipped gracefully if absent so `cargo test`
//! stays green on a fresh clone; CI runs `make test` which builds them).

use lfsr_prune::data::{synth, Batcher, SynthSpec};
use lfsr_prune::lfsr::{GaloisLfsr, MsbMap};
use lfsr_prune::mask::prs::{prs_mask, PrsMaskConfig};
use lfsr_prune::runtime::{ModelRunner, Runtime, StepScalars, Tensor};

fn runtime_or_skip() -> Option<Runtime> {
    let dir = Runtime::default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: no artifacts at {dir:?} — run `make artifacts`");
        return None;
    }
    Some(Runtime::new(dir).expect("runtime"))
}

#[test]
fn mm_demo_matches_host_matmul() {
    let Some(rt) = runtime_or_skip() else { return };
    let k = rt.manifest.kernels["mm_demo"].clone();
    // Shapes fixed at AOT time: x (16,64), w/m (64,32).
    let x: Vec<f32> = (0..16 * 64).map(|i| ((i % 13) as f32 - 6.0) * 0.1).collect();
    let w: Vec<f32> = (0..64 * 32).map(|i| ((i % 7) as f32 - 3.0) * 0.2).collect();
    let m: Vec<f32> = (0..64 * 32).map(|i| ((i * 31 % 10) >= 5) as u32 as f32).collect();
    let outs = rt
        .execute(
            &k.file,
            &[
                Tensor::f32(vec![16, 64], x.clone()),
                Tensor::f32(vec![64, 32], w.clone()),
                Tensor::f32(vec![64, 32], m.clone()),
            ],
        )
        .unwrap();
    let y = outs[0].as_f32();
    // Host reference.
    for r in 0..16 {
        for c in 0..32 {
            let mut acc = 0f32;
            for kk in 0..64 {
                acc += x[r * 64 + kk] * w[kk * 32 + c] * m[kk * 32 + c];
            }
            let got = y[r * 32 + c];
            assert!(
                (got - acc).abs() < 1e-3,
                "({r},{c}): kernel {got} vs host {acc}"
            );
        }
    }
}

#[test]
fn lfsr_idx_artifact_matches_rust_lfsr() {
    // The Pallas jump-matrix kernel (python-built) and the rust Galois
    // LFSR must derive identical index streams — this is the contract
    // that lets the rust coordinator use seeds as the only shared state.
    let Some(rt) = runtime_or_skip() else { return };
    let k = rt.manifest.kernels["lfsr_idx"].clone();
    let n = k.fields["n"] as u32;
    let domain = k.fields["domain"] as usize;
    let (r, c) = (8usize, 128usize);
    let seed = 0x1D3u32;
    let offsets: Vec<i32> = (1..=(r * c) as i32).collect();
    let outs = rt
        .execute(
            &k.file,
            &[
                Tensor::i32(vec![r, c], offsets),
                Tensor::i32(vec![], vec![seed as i32]),
            ],
        )
        .unwrap();
    let got = outs[0].as_i32();
    let mut m = MsbMap::new(GaloisLfsr::new(n, seed), domain);
    for (t, &g) in got.iter().enumerate() {
        let expect = m.next_index();
        assert_eq!(g as usize, expect, "offset {}", t + 1);
    }
}

#[test]
fn lenet300_train_reduces_loss_and_masks_freeze_weights() {
    let Some(rt) = runtime_or_skip() else { return };
    let runner = ModelRunner::new(&rt, "lenet300").unwrap();
    let mut params = runner.init_params(42);
    let masks = runner.dense_masks();
    let data = synth::generate(&SynthSpec::mnist_like(7), 512);
    let mut batcher = Batcher::new(&data, runner.man.batch, 3);

    // Dense: loss must drop.
    let mut first = None;
    let mut last = 0f32;
    for _ in 0..30 {
        let b = batcher.next_batch();
        let (p, loss, _) = runner
            .train_step(&params, &masks, &b, StepScalars::dense(0.1))
            .unwrap();
        params = p;
        first.get_or_insert(loss);
        last = loss;
    }
    assert!(
        last < first.unwrap() * 0.8,
        "loss {} -> {last}",
        first.unwrap()
    );

    // Hard phase with PRS masks: pruned weights exactly zero after a step.
    let midx = runner.maskable_indices();
    let mut prs_masks = Vec::new();
    for (i, &pi) in midx.iter().enumerate() {
        let shape = runner.man.params[pi].shape.clone();
        let cfg = PrsMaskConfig::auto(shape[0], shape[1], 11 + i as u32, 29 + i as u32);
        let m = prs_mask(shape[0], shape[1], 0.7, cfg);
        prs_masks.push(Tensor::f32(shape, m.to_f32()));
    }
    let b = batcher.next_batch();
    let (new_params, _, _) = runner
        .train_step(&params, &prs_masks, &b, StepScalars::retrain(0.05))
        .unwrap();
    for (mi, &pi) in midx.iter().enumerate() {
        let w = new_params[pi].as_f32();
        let m = prs_masks[mi].as_f32();
        let violations = w
            .iter()
            .zip(m)
            .filter(|(w, m)| **m == 0.0 && **w != 0.0)
            .count();
        assert_eq!(violations, 0, "param {pi} has nonzero pruned weights");
    }

    // Eval runs and returns sane numbers.
    let metrics = runner.eval(&params, &masks, &data, Some(256)).unwrap();
    assert!(metrics.accuracy > 0.2, "acc {}", metrics.accuracy);
    assert!(metrics.examples == 256);
}

#[test]
fn regularization_shrinks_prune_targets_via_artifact() {
    let Some(rt) = runtime_or_skip() else { return };
    let runner = ModelRunner::new(&rt, "lenet300").unwrap();
    let params = runner.init_params(1);
    let midx = runner.maskable_indices();
    let mut masks = runner.dense_masks();
    // Mask out half of fc1 as prune targets.
    let shape = runner.man.params[midx[0]].shape.clone();
    let cfg = PrsMaskConfig::auto(shape[0], shape[1], 5, 13);
    let m = prs_mask(shape[0], shape[1], 0.5, cfg);
    masks[0] = Tensor::f32(shape, m.to_f32());

    let data = synth::generate(&SynthSpec::mnist_like(2), 128);
    let mut batcher = Batcher::new(&data, runner.man.batch, 1);
    let b = batcher.next_batch();
    let (new_params, _, _) = runner
        .train_step(
            &params,
            &masks,
            &b,
            StepScalars::regularize(10.0, 0.01, false),
        )
        .unwrap();
    let before = params[midx[0]].as_f32();
    let after = new_params[midx[0]].as_f32();
    let mbytes = masks[0].as_f32();
    let (mut shrunk, mut targets) = (0usize, 0usize);
    for i in 0..before.len() {
        if mbytes[i] == 0.0 && before[i].abs() > 1e-3 {
            targets += 1;
            if after[i].abs() < before[i].abs() {
                shrunk += 1;
            }
        }
    }
    assert!(
        shrunk as f64 > 0.95 * targets as f64,
        "only {shrunk}/{targets} prune-targets shrank"
    );
}
