//! The i8 precision tier under the same microscope as the f32 path:
//!
//! * **bitwise determinism** — a quantized model served through the
//!   blocked kernel must be bit-for-bit equal to the scalar i8 reference
//!   and invariant across worker count × shard count × batch
//!   composition (the exact matrix `kernel_parity.rs` pins for f32:
//!   workers {1, 4} × shards {1, 3, 7} × batch {1, 3, 8, 33}, every
//!   mask family).  Both kernels dequantize each kept entry once
//!   (`q as f32 * scale`) and accumulate in stored-entry order, so
//!   the guarantee carries over by construction — this file checks it.
//! * **numerics** — quantized logits on the demo `synthetic_lenet300`
//!   stay within a pinned tolerance of the f32 logits, and
//!   `argmax_total` top-1 agrees on (almost all) non-adversarial
//!   inputs.  The pins come from a python mirror of the full pipeline
//!   (Pcg32 weights → PRS walk → per-column quantization → f32 op
//!   order): measured max |Δlogit| ≈ 4e-4 across uniform and normal
//!   inputs, 98–100% top-1 agreement — asserted here with ~5x headroom
//!   for libm ulp differences.

use lfsr_prune::data::rng::Pcg32;
use lfsr_prune::mask::prs::PrsMaskConfig;
use lfsr_prune::mask::{magnitude_mask, random_mask};
use lfsr_prune::serve::{
    argmax_total, synthetic_lenet300, CompiledLayer, CompiledModel, InferenceSession,
};
use lfsr_prune::sparse::Precision;

const D0: usize = 37;
const D1: usize = 29;
const D2: usize = 10;

fn weights(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Pcg32::new(seed);
    (0..n).map(|_| rng.next_normal()).collect()
}

/// Two-layer i8 model with one mask method applied to both layers
/// (quantized from the same f32 compile `kernel_parity.rs` uses).
fn quantized_model_for(method: &str, shards: usize) -> CompiledModel {
    let w1 = weights(D0 * D1, 100);
    let w2 = weights(D1 * D2, 101);
    let b1 = weights(D1, 102);
    let b2 = weights(D2, 103);
    let layer = |w: &[f32], b: Vec<f32>, relu: bool, rows: usize, cols: usize, salt: u32| {
        match method {
            "prs" => {
                let cfg = PrsMaskConfig::auto(rows, cols, 13 + salt, 19 + salt);
                CompiledLayer::compile_prs(w, b, relu, rows, cols, 0.75, cfg, shards, 2)
            }
            "magnitude" => {
                let m = magnitude_mask(rows, cols, w, 0.75);
                CompiledLayer::from_mask(w, b, relu, &m, shards)
            }
            "random" => {
                let m = random_mask(rows, cols, 0.75, 7 + salt as u64);
                CompiledLayer::from_mask(w, b, relu, &m, shards)
            }
            other => panic!("unknown method {other}"),
        }
    };
    CompiledModel::new(vec![
        layer(&w1, b1, true, D0, D1, 0),
        layer(&w2, b2, false, D1, D2, 1),
    ])
    .to_precision(Precision::I8)
}

/// Scalar i8 reference forward: per-shard `gemm_into` (which dispatches
/// to the scalar i8 kernel) into a `[batch, width]` buffer, scattered at
/// the shard's column offset — the pre-blocked op order.
fn scalar_forward(model: &CompiledModel, x: &[f32], batch: usize) -> Vec<f32> {
    let mut act = x.to_vec();
    for layer in &model.layers {
        let mut out = vec![0.0f32; batch * layer.cols];
        for shard in &layer.shards {
            let width = shard.width();
            let mut buf = vec![0.0f32; batch * width];
            shard.gemm_into(&act, batch, &layer.bias, layer.relu, &mut buf);
            for b in 0..batch {
                out[b * layer.cols + shard.col_start..b * layer.cols + shard.col_end]
                    .copy_from_slice(&buf[b * width..(b + 1) * width]);
            }
        }
        act = out;
    }
    act
}

#[test]
fn i8_session_bitwise_equals_scalar_reference_any_composition() {
    for method in ["prs", "magnitude", "random"] {
        for shards in [1usize, 3, 7] {
            let model = quantized_model_for(method, shards);
            for workers in [1usize, 4] {
                let session = InferenceSession::new(quantized_model_for(method, shards), workers);
                for batch in [1usize, 3, 8, 33] {
                    let x = weights(batch * D0, 200 + batch as u64);
                    let expect = scalar_forward(&model, &x, batch);
                    let got = session.infer_batch(&x, batch);
                    assert_eq!(got.len(), expect.len());
                    for (i, (&u, &v)) in got.iter().zip(&expect).enumerate() {
                        assert_eq!(
                            u.to_bits(),
                            v.to_bits(),
                            "{method} shards={shards} workers={workers} batch={batch} out {i}"
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn i8_bits_invariant_across_worker_shard_batch_composition() {
    // One fixed input set; every (workers, shards) composition must
    // produce the *same* bits — sharding changes which thread runs which
    // column and the per-column quantization scales see the same kept
    // values either way, so nothing observable may move.
    for method in ["prs", "random"] {
        for batch in [1usize, 3, 8, 33] {
            let x = weights(batch * D0, 400 + batch as u64);
            let baseline =
                InferenceSession::new(quantized_model_for(method, 1), 1).infer_batch(&x, batch);
            for shards in [3usize, 7] {
                for workers in [1usize, 4] {
                    let got = InferenceSession::new(quantized_model_for(method, shards), workers)
                        .infer_batch(&x, batch);
                    for (i, (&u, &v)) in got.iter().zip(&baseline).enumerate() {
                        assert_eq!(
                            u.to_bits(),
                            v.to_bits(),
                            "{method} shards={shards} workers={workers} batch={batch} out {i}"
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn lenet300_quantized_logits_within_pinned_tolerance_of_f32() {
    // Pins from the python mirror of the full pipeline (same Pcg32
    // weights, same walk, same quantizer, f32 op order): max |Δlogit|
    // measured ≈ 4e-4 on both uniform-[0,1) and normal inputs, logit
    // magnitudes ≈ 0.03.  Tolerance pinned at 2e-3 (~5x headroom).
    const TOL: f32 = 2e-3;
    let f32_model = synthetic_lenet300(0.9, 3, 2);
    let q_model = f32_model.to_precision(Precision::I8);
    let f32_sess = InferenceSession::new(f32_model, 2);
    let q_sess = InferenceSession::new(q_model, 2);

    let batch = 256usize;
    let mut rng = Pcg32::new(123);
    let x: Vec<f32> = (0..batch * 784).map(|_| rng.next_f32()).collect();
    let lf = f32_sess.infer_batch(&x, batch);
    let lq = q_sess.infer_batch(&x, batch);

    let mut max_diff = 0.0f32;
    for (&a, &b) in lf.iter().zip(&lq) {
        max_diff = max_diff.max((a - b).abs());
    }
    assert!(max_diff < TOL, "max |Δlogit| {max_diff} exceeds pinned tolerance {TOL}");
    assert!(max_diff > 0.0, "i8 must be a real approximation, not a pass-through");

    // Top-1 agreement on non-adversarial inputs: the mirror measures
    // 98-100%; pin >= 90% so libm ulp skew cannot flake the test, and
    // use the same NaN-safe argmax the serving path uses.
    let agree = (0..batch)
        .filter(|&b| {
            argmax_total(&lf[b * 10..(b + 1) * 10]) == argmax_total(&lq[b * 10..(b + 1) * 10])
        })
        .count();
    assert!(
        agree * 10 >= batch * 9,
        "top-1 agreement {agree}/{batch} below the pinned 90% floor"
    );
}

#[test]
fn quantization_is_idempotent_and_dequantization_is_faithful() {
    // I8 -> I8 is a no-op; I8 -> F32 -> serve computes identical bits to
    // serving the i8 plane directly (dequantization materializes exactly
    // the multipliers the i8 kernel feeds its accumulators).
    let q = quantized_model_for("prs", 3);
    let qq = q.to_precision(Precision::I8);
    let back = q.to_precision(Precision::F32);
    assert_eq!(back.uniform_precision(), Some(Precision::F32));
    let batch = 9usize;
    let x = weights(batch * D0, 500);
    let a = InferenceSession::new(q, 1).infer_batch(&x, batch);
    let b = InferenceSession::new(qq, 4).infer_batch(&x, batch);
    let c = InferenceSession::new(back, 2).infer_batch(&x, batch);
    for (i, ((&u, &v), &w)) in a.iter().zip(&b).zip(&c).enumerate() {
        assert_eq!(u.to_bits(), v.to_bits(), "idempotence, out {i}");
        assert_eq!(u.to_bits(), w.to_bits(), "dequantized f32 twin, out {i}");
    }
}
