//! The quantized precision tiers (i8, i4, ternary) under the same
//! microscope as the f32 path:
//!
//! * **bitwise determinism** — a quantized model served through the
//!   blocked kernel must be bit-for-bit equal to the scalar reference
//!   of its tier and invariant across worker count × shard count ×
//!   batch composition (the exact matrix `kernel_parity.rs` pins for
//!   f32: workers {1, 4} × shards {1, 3, 7} × batch {1, 3, 8, 33},
//!   every mask family).  Both kernels instantiate one generic value
//!   reader per shard call and perform the identical per-(example,
//!   column) f32 op sequence — the multiplier tiers dequantize each
//!   kept entry once (`q as f32 * scale`), ternary accumulates raw
//!   `±x` and applies its column scale once in `finish` — so the
//!   guarantee carries over by construction; this file checks it.
//! * **numerics** — quantized logits on the demo `synthetic_lenet300`
//!   stay within a per-tier pinned tolerance of the f32 logits, and
//!   `argmax_total` top-1 agreement holds a per-tier floor on
//!   non-adversarial inputs.  The pins come from a python mirror of
//!   the full pipeline (`python/tests/test_quant_pins.py`: Pcg32
//!   weights → PRS walk → per-column quantizers → f32 op order).
//!   Measured there (f32 max |logit| ≈ 0.03): i8 max |Δlogit| ≈
//!   2.7e-4 with 256/256 top-1 agreement, i4 ≈ 3.6e-3 with 256/256,
//!   ternary ≈ 1.3e-2 with 233/256 — asserted here with ~5x tolerance
//!   headroom and floors of 90% / 90% / 75% for libm ulp differences.

use lfsr_prune::data::rng::Pcg32;
use lfsr_prune::mask::prs::PrsMaskConfig;
use lfsr_prune::mask::{magnitude_mask, random_mask};
use lfsr_prune::serve::{
    argmax_total, synthetic_lenet300, CompiledLayer, CompiledModel, InferenceSession,
};
use lfsr_prune::sparse::{KernelPath, Precision};

const D0: usize = 37;
const D1: usize = 29;
const D2: usize = 10;

/// Every quantized tier (f32 itself is `kernel_parity.rs`'s job).
const TIERS: [Precision; 3] = [Precision::I8, Precision::I4, Precision::Ternary];

fn weights(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Pcg32::new(seed);
    (0..n).map(|_| rng.next_normal()).collect()
}

/// Two-layer model at one quantized tier with one mask method applied to
/// both layers (quantized from the same f32 compile `kernel_parity.rs`
/// uses).
fn quantized_model_for(method: &str, shards: usize, tier: Precision) -> CompiledModel {
    let w1 = weights(D0 * D1, 100);
    let w2 = weights(D1 * D2, 101);
    let b1 = weights(D1, 102);
    let b2 = weights(D2, 103);
    let layer = |w: &[f32], b: Vec<f32>, relu: bool, rows: usize, cols: usize, salt: u32| {
        match method {
            "prs" => {
                let cfg = PrsMaskConfig::auto(rows, cols, 13 + salt, 19 + salt);
                CompiledLayer::compile_prs(w, b, relu, rows, cols, 0.75, cfg, shards, 2)
            }
            "magnitude" => {
                let m = magnitude_mask(rows, cols, w, 0.75);
                CompiledLayer::from_mask(w, b, relu, &m, shards)
            }
            "random" => {
                let m = random_mask(rows, cols, 0.75, 7 + salt as u64);
                CompiledLayer::from_mask(w, b, relu, &m, shards)
            }
            other => panic!("unknown method {other}"),
        }
    };
    CompiledModel::new(vec![
        layer(&w1, b1, true, D0, D1, 0),
        layer(&w2, b2, false, D1, D2, 1),
    ])
    .to_precision(tier)
}

/// Scalar reference forward: per-shard `gemm_into` (which dispatches to
/// the tier's scalar kernel) into a `[batch, width]` buffer, scattered
/// at the shard's column offset — the pre-blocked op order.
fn scalar_forward(model: &CompiledModel, x: &[f32], batch: usize) -> Vec<f32> {
    let mut act = x.to_vec();
    for layer in &model.layers {
        let mut out = vec![0.0f32; batch * layer.cols];
        for shard in &layer.shards {
            let width = shard.width();
            let mut buf = vec![0.0f32; batch * width];
            shard.gemm_into(&act, batch, &layer.bias, layer.relu, &mut buf);
            for b in 0..batch {
                out[b * layer.cols + shard.col_start..b * layer.cols + shard.col_end]
                    .copy_from_slice(&buf[b * width..(b + 1) * width]);
            }
        }
        act = out;
    }
    act
}

#[test]
fn quantized_session_bitwise_equals_scalar_reference_any_composition() {
    for tier in TIERS {
        for method in ["prs", "magnitude", "random"] {
            for shards in [1usize, 3, 7] {
                let model = quantized_model_for(method, shards, tier);
                for workers in [1usize, 4] {
                    let mut session =
                        InferenceSession::new(quantized_model_for(method, shards, tier), workers);
                    // `gemm_into` is the scalar op order — pin the session
                    // so the bitwise compare survives a SIMD default
                    // (SIMD-vs-scalar parity lives in kernel_parity.rs).
                    session.set_kernel_path(KernelPath::Scalar);
                    for batch in [1usize, 3, 8, 33] {
                        let x = weights(batch * D0, 200 + batch as u64);
                        let expect = scalar_forward(&model, &x, batch);
                        let got = session.infer_batch(&x, batch);
                        assert_eq!(got.len(), expect.len());
                        for (i, (&u, &v)) in got.iter().zip(&expect).enumerate() {
                            assert_eq!(
                                u.to_bits(),
                                v.to_bits(),
                                "{tier} {method} shards={shards} workers={workers} \
                                 batch={batch} out {i}"
                            );
                        }
                    }
                }
            }
        }
    }
}

#[test]
fn quantized_bits_invariant_across_worker_shard_batch_composition() {
    // One fixed input set; every (workers, shards) composition must
    // produce the *same* bits at every tier — sharding changes which
    // thread runs which column, but the per-column stats (i8/i4 scale,
    // ternary threshold + scale) see the same kept values in the same
    // stored order either way, so nothing observable may move.
    for tier in TIERS {
        for method in ["prs", "random"] {
            for batch in [1usize, 3, 8, 33] {
                let x = weights(batch * D0, 400 + batch as u64);
                let baseline = InferenceSession::new(quantized_model_for(method, 1, tier), 1)
                    .infer_batch(&x, batch);
                for shards in [3usize, 7] {
                    for workers in [1usize, 4] {
                        let got =
                            InferenceSession::new(quantized_model_for(method, shards, tier), workers)
                                .infer_batch(&x, batch);
                        for (i, (&u, &v)) in got.iter().zip(&baseline).enumerate() {
                            assert_eq!(
                                u.to_bits(),
                                v.to_bits(),
                                "{tier} {method} shards={shards} workers={workers} \
                                 batch={batch} out {i}"
                            );
                        }
                    }
                }
            }
        }
    }
}

#[test]
fn lenet300_quantized_logits_within_pinned_tolerance_of_f32() {
    // Pins from python/tests/test_quant_pins.py (same Pcg32 weights,
    // same walk, same quantizers, f32 op order); measured max |Δlogit|
    // ≈ 2.7e-4 (i8), 3.6e-3 (i4), 1.3e-2 (ternary) against f32 logit
    // magnitudes ≈ 0.03, with top-1 agreement 256/256, 256/256, and
    // 233/256.  Tolerances pinned with ~5x headroom; top-1 floors use
    // the same NaN-safe argmax the serving path uses.  `floor_num /
    // floor_den` is the agreement floor as a fraction of the batch.
    let pins: [(Precision, f32, usize, usize); 3] = [
        (Precision::I8, 2e-3, 9, 10),      // >= 90%
        (Precision::I4, 2e-2, 9, 10),      // >= 90%
        (Precision::Ternary, 6e-2, 3, 4),  // >= 75%
    ];
    let f32_model = synthetic_lenet300(0.9, 3, 2);
    let f32_sess = InferenceSession::new(f32_model.clone(), 2);

    let batch = 256usize;
    let mut rng = Pcg32::new(123);
    let x: Vec<f32> = (0..batch * 784).map(|_| rng.next_f32()).collect();
    let lf = f32_sess.infer_batch(&x, batch);

    let mut prev_max = 0.0f32;
    for (tier, tol, floor_num, floor_den) in pins {
        let q_sess = InferenceSession::new(f32_model.to_precision(tier), 2);
        let lq = q_sess.infer_batch(&x, batch);

        let mut max_diff = 0.0f32;
        for (&a, &b) in lf.iter().zip(&lq) {
            max_diff = max_diff.max((a - b).abs());
        }
        assert!(max_diff < tol, "{tier}: max |Δlogit| {max_diff} exceeds pinned tolerance {tol}");
        assert!(max_diff > 0.0, "{tier} must be a real approximation, not a pass-through");
        // The coarser the tier, the larger the error — the ladder held
        // at derivation time and must keep holding on this input set.
        assert!(max_diff > prev_max, "{tier}: expected max |Δlogit| above {prev_max}");
        prev_max = max_diff;

        let agree = (0..batch)
            .filter(|&b| {
                argmax_total(&lf[b * 10..(b + 1) * 10])
                    == argmax_total(&lq[b * 10..(b + 1) * 10])
            })
            .count();
        assert!(
            agree * floor_den >= batch * floor_num,
            "{tier}: top-1 agreement {agree}/{batch} below the pinned \
             {floor_num}/{floor_den} floor"
        );
    }
}

#[test]
fn quantization_is_idempotent_and_dequantization_is_faithful() {
    // tier -> tier is a no-op at every tier.  The dequantized f32 twin
    // is *bitwise* for the multiplier tiers (i8/i4 dequantization
    // materializes exactly the `q as f32 * scale` multipliers the
    // kernel feeds its accumulators) but only *numerically close* for
    // ternary: the ternary kernel sums raw ±x and multiplies by the
    // column scale once, while its f32 twin multiplies `±scale` into
    // every entry — same math, different f32 op order.
    let batch = 9usize;
    let x = weights(batch * D0, 500);
    for tier in TIERS {
        let q = quantized_model_for("prs", 3, tier);
        let qq = q.to_precision(tier);
        let back = q.to_precision(Precision::F32);
        assert_eq!(back.uniform_precision(), Some(Precision::F32));
        // Pinned scalar: the i8/i4-vs-twin bitwise claim depends on the
        // scalar op order (SIMD factors the scale out of the inner loop,
        // the f32 twin multiplies it in — same math, different bits).
        let scalar_infer = |model: CompiledModel, workers: usize| {
            let mut s = InferenceSession::new(model, workers);
            s.set_kernel_path(KernelPath::Scalar);
            s.infer_batch(&x, batch)
        };
        let a = scalar_infer(q, 1);
        let b = scalar_infer(qq, 4);
        let c = scalar_infer(back, 2);
        for (i, ((&u, &v), &w)) in a.iter().zip(&b).zip(&c).enumerate() {
            assert_eq!(u.to_bits(), v.to_bits(), "{tier} idempotence, out {i}");
            if tier == Precision::Ternary {
                assert!(
                    (u - w).abs() <= 1e-4 * u.abs().max(1.0),
                    "{tier} dequantized f32 twin drifted: {u} vs {w}, out {i}"
                );
            } else {
                assert_eq!(u.to_bits(), w.to_bits(), "{tier} dequantized f32 twin, out {i}");
            }
        }
    }
}
