//! End-to-end pipeline integration: the paper's 4-stage process over the
//! AOT artifacts, at reduced step counts (full-scale runs live in the
//! experiment harness; see EXPERIMENTS.md).

use lfsr_prune::pipeline::{
    baseline_config, run_trial, trials, DataConfig, MaskMethod, PipelineConfig, RegType,
};
use lfsr_prune::runtime::Runtime;

fn short_cfg() -> PipelineConfig {
    PipelineConfig {
        model: "lenet300".into(),
        data: DataConfig::MnistLike,
        method: MaskMethod::Prs { seed_base: 0xACE1 },
        sparsity: 0.7,
        lam: 2.0,
        reg: RegType::L2,
        dense_steps: 60,
        reg_steps: 40,
        retrain_steps: 40,
        lr_dense: 0.1,
        lr_reg: 0.05,
        lr_retrain: 0.02,
        n_train: 1024,
        n_eval: 512,
        trial_seed: 1,
        eval_limit: Some(256),
        output_layer_factor: 0.8,
    }
}

fn have_artifacts() -> bool {
    Runtime::default_dir().join("manifest.json").exists()
}

#[test]
fn prs_pipeline_end_to_end() {
    if !have_artifacts() {
        eprintln!("SKIP: run `make artifacts`");
        return;
    }
    let rt = Runtime::new(Runtime::default_dir()).unwrap();
    let cfg = short_cfg();
    let mut curve: Vec<(String, f32)> = Vec::new();
    let mut cb = |phase: &str, _i: usize, loss: f32| curve.push((phase.to_string(), loss));
    let r = run_trial(&rt, &cfg, Some(&mut cb)).unwrap();

    // Dense model learned something well above chance (10 classes).
    assert!(r.dense.accuracy > 0.5, "dense acc {}", r.dense.accuracy);
    // Masks hit the target sparsity exactly (output layer gets the
    // configured relief factor).
    for (i, m) in r.masks.iter().enumerate() {
        let expect = if i == r.masks.len() - 1 { 0.7 * 0.8 } else { 0.7 };
        assert!(
            (m.sparsity() - expect).abs() < 2e-3,
            "mask {i} sp {} expect {expect}",
            m.sparsity()
        );
    }
    // Retraining recovers accuracy relative to the raw pruned model.
    assert!(
        r.retrained.accuracy >= r.pruned.accuracy - 0.02,
        "retrain {} vs pruned {}",
        r.retrained.accuracy,
        r.pruned.accuracy
    );
    // Compression accounting: lenet300 at 70% FC sparsity ≈ 3.3x.
    let cr = r.compression_rate();
    assert!(cr > 2.5 && cr < 4.5, "compression {cr}");
    // Loss curve recorded for all three training phases.
    for phase in ["dense", "regularize", "retrain"] {
        assert!(curve.iter().any(|(p, _)| p == phase), "missing {phase}");
    }
}

#[test]
fn baseline_pipeline_and_trial_runner() {
    if !have_artifacts() {
        eprintln!("SKIP: run `make artifacts`");
        return;
    }
    // Two jobs (PRS + magnitude baseline) across 2 workers; exercises the
    // leader/worker coordinator with per-thread PJRT clients.
    let mut prs = short_cfg();
    prs.dense_steps = 40;
    prs.reg_steps = 25;
    prs.retrain_steps = 25;
    let base = baseline_config(prs.clone());
    let jobs = vec![
        trials::TrialJob {
            key: "prs@0.7".into(),
            config: prs,
        },
        trials::TrialJob {
            key: "magnitude@0.7".into(),
            config: base,
        },
    ];
    let outcomes = trials::run_trials(Runtime::default_dir(), jobs, 2, false);
    assert_eq!(outcomes.len(), 2);
    for o in &outcomes {
        let r = o.result.as_ref().expect("trial failed");
        assert!(r.retrained.accuracy > 0.3, "{}: {}", o.key, r.retrained.accuracy);
    }
    let aggs = trials::aggregate(&outcomes);
    assert_eq!(aggs.len(), 2);
    assert!(aggs.iter().all(|a| a.n == 1));
}

#[test]
fn magnitude_baseline_beats_chance_after_heavy_prune() {
    if !have_artifacts() {
        eprintln!("SKIP: run `make artifacts`");
        return;
    }
    let rt = Runtime::new(Runtime::default_dir()).unwrap();
    let mut cfg = baseline_config(short_cfg());
    cfg.sparsity = 0.9;
    let r = run_trial(&rt, &cfg, None).unwrap();
    // Magnitude pruning at 90% keeps the most useful synapses: even before
    // retraining it should beat chance on this easy task.
    assert!(r.pruned.accuracy > 0.2, "pruned acc {}", r.pruned.accuracy);
    assert!(r.retrained.accuracy > 0.5, "retrained {}", r.retrained.accuracy);
}
