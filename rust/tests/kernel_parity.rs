//! Property-style parity: the batch-major register-blocked kernel that
//! now drives `InferenceSession` must be **bit-for-bit** equal to the
//! scalar reference (`PackedColumns::gemm_into` + scatter — the serving
//! path before this kernel landed) across batch sizes, shard counts,
//! worker counts, and every mask family — plus arena-reuse and NaN
//! argmax behaviour.
//!
//! Kernel paths: the scalar-oracle pins run on a session pinned to
//! `KernelPath::Scalar`; the SIMD path gets its own parity matrix
//! (SIMD ≡ SIMD bitwise across worker × shard × batch × tier
//! composition, SIMD vs scalar within the per-tier budgets
//! `python/tests/test_simd_pins.py` derives, ternary bitwise).

use lfsr_prune::data::rng::Pcg32;
use lfsr_prune::mask::prs::PrsMaskConfig;
use lfsr_prune::mask::{magnitude_mask, random_mask};
use lfsr_prune::serve::{argmax_total, CompiledLayer, CompiledModel, InferenceSession};
use lfsr_prune::sparse::{KernelPath, Precision};

const D0: usize = 37;
const D1: usize = 29;
const D2: usize = 10;

fn weights(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Pcg32::new(seed);
    (0..n).map(|_| rng.next_normal()).collect()
}

/// Two-layer model with one mask method applied to both layers.
fn model_for(method: &str, shards: usize) -> CompiledModel {
    let w1 = weights(D0 * D1, 100);
    let w2 = weights(D1 * D2, 101);
    let b1 = weights(D1, 102);
    let b2 = weights(D2, 103);
    let layer = |w: &[f32], b: Vec<f32>, relu: bool, rows: usize, cols: usize, salt: u32| {
        match method {
            "prs" => {
                let cfg = PrsMaskConfig::auto(rows, cols, 13 + salt, 19 + salt);
                CompiledLayer::compile_prs(w, b, relu, rows, cols, 0.75, cfg, shards, 2)
            }
            "magnitude" => {
                let m = magnitude_mask(rows, cols, w, 0.75);
                CompiledLayer::from_mask(w, b, relu, &m, shards)
            }
            "random" => {
                let m = random_mask(rows, cols, 0.75, 7 + salt as u64);
                CompiledLayer::from_mask(w, b, relu, &m, shards)
            }
            other => panic!("unknown method {other}"),
        }
    };
    CompiledModel::new(vec![
        layer(&w1, b1, true, D0, D1, 0),
        layer(&w2, b2, false, D1, D2, 1),
    ])
}

/// Scalar reference forward: the pre-blocked serving path — per-shard
/// `gemm_into` into a `[batch, width]` buffer, scattered into the layer
/// output at the shard's column offset.
fn scalar_forward(model: &CompiledModel, x: &[f32], batch: usize) -> Vec<f32> {
    let mut act = x.to_vec();
    for layer in &model.layers {
        let mut out = vec![0.0f32; batch * layer.cols];
        for shard in &layer.shards {
            let width = shard.width();
            let mut buf = vec![0.0f32; batch * width];
            shard.gemm_into(&act, batch, &layer.bias, layer.relu, &mut buf);
            for b in 0..batch {
                out[b * layer.cols + shard.col_start..b * layer.cols + shard.col_end]
                    .copy_from_slice(&buf[b * width..(b + 1) * width]);
            }
        }
        act = out;
    }
    act
}

#[test]
fn blocked_session_bitwise_equals_scalar_reference() {
    for method in ["prs", "magnitude", "random"] {
        for shards in [1usize, 4, 7] {
            let model = model_for(method, shards);
            for workers in [1usize, 4] {
                let mut session = InferenceSession::new(model_for(method, shards), workers);
                // The scalar reference is the scalar op order — pin the
                // session so the bitwise compare survives a SIMD default.
                session.set_kernel_path(KernelPath::Scalar);
                for batch in [1usize, 3, 8, 33] {
                    let x = weights(batch * D0, 200 + batch as u64);
                    let expect = scalar_forward(&model, &x, batch);
                    let got = session.infer_batch(&x, batch);
                    assert_eq!(got.len(), expect.len());
                    for (i, (&u, &v)) in got.iter().zip(&expect).enumerate() {
                        assert_eq!(
                            u.to_bits(),
                            v.to_bits(),
                            "{method} shards={shards} workers={workers} batch={batch} out {i}"
                        );
                    }
                }
            }
        }
    }
}

/// Per-tier SIMD↔scalar budget, matching `python/tests/test_simd_pins.py`:
/// measured worst-case drift is ~3e-6 (f32 ~7.5e-7); 2e-5 gives >= 6x
/// headroom. Ternary SIMD shares the scalar op order exactly, so its
/// budget is zero (bitwise).
fn simd_budget(tier: Precision) -> f32 {
    match tier {
        Precision::Ternary => 0.0,
        _ => 2e-5,
    }
}

#[test]
fn simd_session_parity_matrix_across_worker_shard_batch_tier() {
    // If the host has no SIMD path, ForceSimd resolves to scalar and this
    // degenerates into a second scalar-vs-scalar bitwise run — still a
    // valid (if redundant) check, so no skip logic is needed.
    for tier in [
        Precision::F32,
        Precision::I8,
        Precision::I4,
        Precision::Ternary,
    ] {
        let budget = simd_budget(tier);
        for shards in [1usize, 3, 7] {
            let model = model_for("prs", shards).to_precision(tier);
            let mut scalar_session = InferenceSession::new(model.clone(), 1);
            scalar_session.set_kernel_path(KernelPath::Scalar);
            // batch=1 single-worker SIMD run is the within-path oracle:
            // every other composition must reproduce it bit-for-bit.
            let mut oracle = InferenceSession::new(model.clone(), 1);
            oracle.set_kernel_path(KernelPath::ForceSimd);
            for workers in [1usize, 4] {
                let mut session = InferenceSession::new(model.clone(), workers);
                session.set_kernel_path(KernelPath::ForceSimd);
                for batch in [1usize, 3, 8, 33] {
                    let x = weights(batch * D0, 400 + batch as u64);
                    let simd = session.infer_batch(&x, batch);
                    let scalar = scalar_session.infer_batch(&x, batch);
                    let ctx = format!("tier={tier:?} shards={shards} workers={workers} batch={batch}");
                    // (1) SIMD ≡ SIMD bitwise across worker/batch composition:
                    // each row must equal the same row inferred alone on the
                    // single-worker oracle session.
                    for b in 0..batch {
                        let row = &x[b * D0..(b + 1) * D0];
                        let alone = oracle.infer_batch(row, 1);
                        for (i, (&u, &v)) in
                            simd[b * D2..(b + 1) * D2].iter().zip(&alone).enumerate()
                        {
                            assert_eq!(
                                u.to_bits(),
                                v.to_bits(),
                                "{ctx}: SIMD row {b} out {i} diverged from batch-1 oracle"
                            );
                        }
                    }
                    // (2) SIMD vs scalar within the pinned per-tier budget
                    // (bitwise for ternary, where budget == 0).
                    for (i, (&u, &v)) in simd.iter().zip(&scalar).enumerate() {
                        if budget == 0.0 {
                            assert_eq!(u.to_bits(), v.to_bits(), "{ctx}: out {i} (ternary)");
                        } else {
                            let err = (u - v).abs();
                            let tol = budget * v.abs().max(1.0);
                            assert!(
                                err <= tol,
                                "{ctx}: out {i} |{u} - {v}| = {err} > {tol}"
                            );
                        }
                    }
                }
            }
        }
    }
}

#[test]
fn consecutive_calls_through_warm_arena_are_identical() {
    let session = InferenceSession::new(model_for("prs", 3), 4);
    for batch in [1usize, 8, 33] {
        let x = weights(batch * D0, 300 + batch as u64);
        let first = session.infer_batch(&x, batch);
        let second = session.infer_batch(&x, batch);
        for (i, (&u, &v)) in first.iter().zip(&second).enumerate() {
            assert_eq!(u.to_bits(), v.to_bits(), "batch {batch} out {i}");
        }
    }
}

#[test]
fn nan_logits_classify_deterministically() {
    // A dense layer whose weights inject NaN/Inf into specific logits:
    // classify_batch must not panic and must follow the documented
    // total_cmp order (positive-bit NaN on top, first index wins ties).
    use lfsr_prune::mask::Mask;
    let (rows, cols) = (4usize, 3usize);
    // x = all ones, so logit c = sum of column c.
    let mut w = vec![0.0f32; rows * cols];
    w[0] = 1.0; // logit 0 = 1.0
    w[1] = f32::NAN; // logit 1 = NaN
    w[2] = 5.0; // logit 2 = 5.0
    let layer = CompiledLayer::from_mask(&w, Vec::new(), false, &Mask::dense(rows, cols), 1);
    let session = InferenceSession::new(CompiledModel::new(vec![layer]), 1);
    let x = vec![1.0f32; rows];
    let logits = session.infer_one(&x);
    assert!(logits[1].is_nan(), "test setup: logit 1 must be NaN");
    let classes = session.classify_batch(&x, 1);
    // NaN (positive bit pattern) tops the total order.
    assert_eq!(classes[0], 1);
    // And argmax_total never panics on all-NaN / mixed rows.
    assert_eq!(argmax_total(&[f32::NAN, f32::NAN, f32::NAN]), 0);
    assert_eq!(argmax_total(&[2.0, 2.0]), 0);
}
