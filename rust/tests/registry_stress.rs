//! Registry stress: mixed FC + conv tenants (f32 and i8 tiers) on ONE
//! shared worker pool, with concurrent pushes, drains, and artifact
//! load/evict churn in flight — and every answer bitwise-identical to
//! the same model served alone.  The serving contract under
//! multi-tenancy is not "approximately right under load": tenant mix,
//! drain interleaving, and registry churn must not move a single bit.

use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use lfsr_prune::data::rng::Pcg32;
use lfsr_prune::serve::{
    synthetic_lenet300_seeded, synthetic_vgg16_scaled, CompiledModel, InferenceSession,
};
use lfsr_prune::sparse::Precision;
use lfsr_prune::store::{export_model, LoadOptions, ModelRegistry, TenantConfig};

/// Deterministic per-request input, independent of push order.
fn request_input(dim: usize, id: u64) -> Vec<f32> {
    let mut rng = Pcg32::new(0x5EED ^ id);
    (0..dim).map(|_| rng.next_normal()).collect()
}

/// `load_while_serving_keeps_established_tenant_bitwise` arms the
/// process-global faultpoint plan against `store.decode`; every test in
/// this binary that loads artifacts must serialize against it.
static DECODERS: Mutex<()> = Mutex::new(());

#[test]
fn mixed_fc_conv_tenants_bitwise_under_concurrent_churn() {
    let _serial = DECODERS.lock().unwrap_or_else(|e| e.into_inner());
    let n_each = 16usize;
    let fc = synthetic_lenet300_seeded(0.9, 3, 1, 11);
    let vgg = synthetic_vgg16_scaled(16, 16, 0.9, 3, 1);
    let tenants: Vec<(&str, CompiledModel)> = vec![
        ("fc-f32", fc.clone()),
        ("fc-i8", fc.to_precision(Precision::I8)),
        ("vgg-f32", vgg.clone()),
        ("vgg-i8", vgg.to_precision(Precision::I8)),
    ];

    // Ground truth: each tenant's answers computed ALONE (inline
    // single-worker session — serving is bitwise invariant to pool and
    // batch composition, which is exactly what this test then proves
    // under multi-tenant churn).
    let expected: Vec<Vec<Vec<f32>>> = tenants
        .iter()
        .map(|(_, model)| {
            let solo = InferenceSession::new(model.clone(), 1);
            (0..n_each)
                .map(|id| solo.infer_one(&request_input(model.in_dim(), id as u64)))
                .collect()
        })
        .collect();

    let reg = Arc::new(ModelRegistry::new(2));
    let cfg = TenantConfig {
        batch: 4,
        max_wait: Some(Duration::from_millis(1)),
        span_sample_every: 1,
        ..TenantConfig::default()
    };
    for (id, model) in &tenants {
        reg.insert(id, model.clone(), cfg).unwrap();
    }

    // Churn artifact for load/evict traffic: a real .lfsrpack round
    // trip per cycle, on the same shared pool.
    let churn_path = std::env::temp_dir()
        .join(format!("lfsrpack_stress_{}.lfsrpack", std::process::id()));
    export_model(&synthetic_lenet300_seeded(0.95, 2, 1, 71), &churn_path, 1).expect("export");

    let pushers: Vec<_> = tenants
        .iter()
        .enumerate()
        .map(|(ti, (id, model))| {
            let reg = Arc::clone(&reg);
            let id = id.to_string();
            let dim = model.in_dim();
            std::thread::spawn(move || {
                for k in 0..n_each {
                    let rid = (ti * n_each + k) as u64;
                    reg.push(&id, rid, request_input(dim, k as u64)).unwrap();
                }
            })
        })
        .collect();
    let churner = {
        let reg = Arc::clone(&reg);
        let path = churn_path.clone();
        std::thread::spawn(move || {
            for round in 0..6 {
                let opts = LoadOptions {
                    n_shards: 2,
                    lanes: 1,
                    verify: false,
                    precision: if round % 2 == 0 { None } else { Some(Precision::I8) },
                };
                reg.load("churn", &path, &opts, TenantConfig::default()).unwrap();
                reg.push("churn", 9000 + round, vec![0.25; 784]).unwrap();
                assert!(reg.contains("churn"));
                let _ = reg.list(); // list() races with load/evict by design
                assert!(reg.evict("churn").is_some());
            }
        })
    };

    // Drain concurrently with the pushes and the churn.
    let total = tenants.len() * n_each;
    let mut answers = Vec::new();
    let t0 = Instant::now();
    while answers.len() < total {
        assert!(t0.elapsed() < Duration::from_secs(60), "drain stalled");
        let done = pushers.iter().all(|h| h.is_finished());
        for ans in reg.drain(done) {
            if ans.model != "churn" {
                answers.push(ans);
            }
        }
    }
    for h in pushers {
        h.join().unwrap();
    }
    churner.join().unwrap();
    let _ = std::fs::remove_file(&churn_path);

    // Every answer equals its solo-serving reference, bit for bit —
    // tenant mix, shared pool, churn, and batch padding included.
    assert_eq!(answers.len(), total);
    let mut seen = vec![false; total];
    for ans in &answers {
        let ti = tenants.iter().position(|(id, _)| *id == ans.model).unwrap();
        let k = ans.request as usize - ti * n_each;
        assert!(!seen[ans.request as usize], "duplicate answer {}", ans.request);
        seen[ans.request as usize] = true;
        let reference = &expected[ti][k];
        assert_eq!(ans.logits.len(), reference.len());
        for (i, (&u, &v)) in ans.logits.iter().zip(reference).enumerate() {
            assert_eq!(
                u.to_bits(),
                v.to_bits(),
                "{}#{k} logit {i} differs from solo serving",
                ans.model
            );
        }
    }
    assert!(seen.iter().all(|&s| s), "every request answered exactly once");
}

/// Evict-while-inflight: a tenant evicted while a drain thread is
/// serving concurrently must account for every accepted request —
/// answered before the evict, or shed (and counted) by it — never
/// silently dropped.  The surviving tenant's answers stay bitwise
/// through the churn.
#[test]
fn evict_while_inflight_sheds_and_counts_queued_requests() {
    let n_rounds = 8usize;
    let keeper = synthetic_lenet300_seeded(0.9, 2, 1, 51);
    let victim = synthetic_lenet300_seeded(0.9, 2, 1, 53);
    let dim = keeper.in_dim();
    let solo = InferenceSession::new(keeper.clone(), 1);

    let reg = Arc::new(ModelRegistry::new(2));
    let cfg = TenantConfig {
        batch: 4,
        max_wait: None,
        span_sample_every: 1,
        ..TenantConfig::default()
    };
    reg.insert("keeper", keeper, cfg).unwrap();

    for round in 0..n_rounds {
        let id = format!("victim{round}");
        reg.insert(&id, victim.clone(), cfg).unwrap();
        // Drain concurrently with the pushes and the evict below.
        let drainer = {
            let reg = Arc::clone(&reg);
            std::thread::spawn(move || {
                let mut answers = Vec::new();
                for _ in 0..64 {
                    answers.extend(reg.drain(true));
                }
                answers
            })
        };
        let mut victim_accepted = 0u64;
        for k in 0..16u64 {
            reg.push("keeper", round as u64 * 100 + k, request_input(dim, k)).unwrap();
            reg.push(&id, 1000 + k, request_input(dim, k)).unwrap();
            victim_accepted += 1;
        }
        let shed = reg.evict(&id).expect("victim registered") as u64;
        assert!(reg.evict(&id).is_none(), "double evict reports missing");
        assert!(
            reg.push(&id, 9999, request_input(dim, 0)).is_err(),
            "pushes after the evict are NoSuchModel"
        );
        let mut answers = drainer.join().unwrap();
        // Finish the keeper's queue (the victim's is gone).
        while reg.pending() > 0 {
            answers.extend(reg.drain(true));
        }
        // A micro-batch already in flight at evict time still completes
        // (the drain holds the entry alive); everything still queued was
        // shed and counted.  Nothing vanishes.
        let victim_answered = answers.iter().filter(|a| a.model == id).count() as u64;
        assert_eq!(
            victim_answered + shed,
            victim_accepted,
            "round {round}: every accepted victim request is answered or shed"
        );
        for ans in answers.iter().filter(|a| a.model == "keeper") {
            let reference = solo.infer_one(&request_input(dim, ans.request % 100));
            for (i, (&u, &v)) in ans.logits.iter().zip(&reference).enumerate() {
                assert_eq!(
                    u.to_bits(),
                    v.to_bits(),
                    "keeper#{} logit {i} differs from solo serving during evict churn",
                    ans.request
                );
            }
        }
    }
}

/// Load-while-serving: artifact loads (including one the faultpoint
/// harness forces to fail) land new tenants while an existing tenant
/// is mid-traffic; the established tenant's answers stay bitwise and
/// the failed load leaves no trace.
#[test]
fn load_while_serving_keeps_established_tenant_bitwise() {
    use lfsr_prune::obs::faultpoint::{self, points};
    use lfsr_prune::obs::{FaultAction, FaultPlan};

    let _serial = DECODERS.lock().unwrap_or_else(|e| e.into_inner());

    let keeper = synthetic_lenet300_seeded(0.9, 2, 1, 61);
    let dim = keeper.in_dim();
    let solo = InferenceSession::new(keeper.clone(), 1);
    let reg = Arc::new(ModelRegistry::new(2));
    let cfg = TenantConfig {
        batch: 4,
        max_wait: None,
        span_sample_every: 1,
        ..TenantConfig::default()
    };
    reg.insert("keeper", keeper, cfg).unwrap();

    let path = std::env::temp_dir()
        .join(format!("lfsrpack_loadserve_{}.lfsrpack", std::process::id()));
    export_model(&synthetic_lenet300_seeded(0.95, 2, 1, 67), &path, 1).expect("export");

    // Every 3rd decode is forced to fail: load-while-serving must
    // tolerate bad artifacts mid-churn.  (Faultpoint state is global;
    // this test owns it for its duration.)
    let plan = FaultPlan::seeded(5).with_prob(
        points::STORE_DECODE,
        None,
        FaultAction::Fail,
        1,
        u64::MAX,
        0.33,
    );
    let _g = faultpoint::arm(&plan);

    let loader = {
        let reg = Arc::clone(&reg);
        let path = path.clone();
        std::thread::spawn(move || {
            let opts = LoadOptions { n_shards: 2, lanes: 1, verify: false, precision: None };
            let mut loaded = 0u32;
            for round in 0..12 {
                let id = format!("side{round}");
                match reg.load(&id, &path, &opts, TenantConfig::default()) {
                    Ok(()) => {
                        loaded += 1;
                        assert!(reg.contains(&id));
                        reg.evict(&id).unwrap();
                    }
                    Err(e) => {
                        // The forced decode failure is typed and leaves
                        // nothing registered.
                        assert!(e.to_string().contains("faultpoint"), "{e}");
                        assert!(!reg.contains(&id));
                    }
                }
            }
            loaded
        })
    };

    let n = 32usize;
    for k in 0..n as u64 {
        reg.push("keeper", k, request_input(dim, k)).unwrap();
    }
    let mut answers = Vec::new();
    let t0 = Instant::now();
    while answers.len() < n {
        assert!(t0.elapsed() < Duration::from_secs(60), "drain stalled");
        answers.extend(reg.drain(true).into_iter().filter(|a| a.model == "keeper"));
    }
    loader.join().unwrap();
    let _ = std::fs::remove_file(&path);

    for ans in &answers {
        let reference = solo.infer_one(&request_input(dim, ans.request));
        for (i, (&u, &v)) in ans.logits.iter().zip(&reference).enumerate() {
            assert_eq!(
                u.to_bits(),
                v.to_bits(),
                "keeper#{} logit {i} differs from solo serving during load churn",
                ans.request
            );
        }
    }
}
