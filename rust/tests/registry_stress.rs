//! Registry stress: mixed FC + conv tenants (f32 and i8 tiers) on ONE
//! shared worker pool, with concurrent pushes, drains, and artifact
//! load/evict churn in flight — and every answer bitwise-identical to
//! the same model served alone.  The serving contract under
//! multi-tenancy is not "approximately right under load": tenant mix,
//! drain interleaving, and registry churn must not move a single bit.

use std::sync::Arc;
use std::time::{Duration, Instant};

use lfsr_prune::data::rng::Pcg32;
use lfsr_prune::serve::{
    synthetic_lenet300_seeded, synthetic_vgg16_scaled, CompiledModel, InferenceSession,
};
use lfsr_prune::sparse::Precision;
use lfsr_prune::store::{export_model, LoadOptions, ModelRegistry, TenantConfig};

/// Deterministic per-request input, independent of push order.
fn request_input(dim: usize, id: u64) -> Vec<f32> {
    let mut rng = Pcg32::new(0x5EED ^ id);
    (0..dim).map(|_| rng.next_normal()).collect()
}

#[test]
fn mixed_fc_conv_tenants_bitwise_under_concurrent_churn() {
    let n_each = 16usize;
    let fc = synthetic_lenet300_seeded(0.9, 3, 1, 11);
    let vgg = synthetic_vgg16_scaled(16, 16, 0.9, 3, 1);
    let tenants: Vec<(&str, CompiledModel)> = vec![
        ("fc-f32", fc.clone()),
        ("fc-i8", fc.to_precision(Precision::I8)),
        ("vgg-f32", vgg.clone()),
        ("vgg-i8", vgg.to_precision(Precision::I8)),
    ];

    // Ground truth: each tenant's answers computed ALONE (inline
    // single-worker session — serving is bitwise invariant to pool and
    // batch composition, which is exactly what this test then proves
    // under multi-tenant churn).
    let expected: Vec<Vec<Vec<f32>>> = tenants
        .iter()
        .map(|(_, model)| {
            let solo = InferenceSession::new(model.clone(), 1);
            (0..n_each)
                .map(|id| solo.infer_one(&request_input(model.in_dim(), id as u64)))
                .collect()
        })
        .collect();

    let reg = Arc::new(ModelRegistry::new(2));
    let cfg =
        TenantConfig { batch: 4, max_wait: Some(Duration::from_millis(1)), span_sample_every: 1 };
    for (id, model) in &tenants {
        reg.insert(id, model.clone(), cfg).unwrap();
    }

    // Churn artifact for load/evict traffic: a real .lfsrpack round
    // trip per cycle, on the same shared pool.
    let churn_path = std::env::temp_dir()
        .join(format!("lfsrpack_stress_{}.lfsrpack", std::process::id()));
    export_model(&synthetic_lenet300_seeded(0.95, 2, 1, 71), &churn_path, 1).expect("export");

    let pushers: Vec<_> = tenants
        .iter()
        .enumerate()
        .map(|(ti, (id, model))| {
            let reg = Arc::clone(&reg);
            let id = id.to_string();
            let dim = model.in_dim();
            std::thread::spawn(move || {
                for k in 0..n_each {
                    let rid = (ti * n_each + k) as u64;
                    reg.push(&id, rid, request_input(dim, k as u64)).unwrap();
                }
            })
        })
        .collect();
    let churner = {
        let reg = Arc::clone(&reg);
        let path = churn_path.clone();
        std::thread::spawn(move || {
            for round in 0..6 {
                let opts = LoadOptions {
                    n_shards: 2,
                    lanes: 1,
                    verify: false,
                    precision: if round % 2 == 0 { None } else { Some(Precision::I8) },
                };
                reg.load("churn", &path, &opts, TenantConfig::default()).unwrap();
                reg.push("churn", 9000 + round, vec![0.25; 784]).unwrap();
                assert!(reg.contains("churn"));
                let _ = reg.list(); // list() races with load/evict by design
                assert!(reg.evict("churn"));
            }
        })
    };

    // Drain concurrently with the pushes and the churn.
    let total = tenants.len() * n_each;
    let mut answers = Vec::new();
    let t0 = Instant::now();
    while answers.len() < total {
        assert!(t0.elapsed() < Duration::from_secs(60), "drain stalled");
        let done = pushers.iter().all(|h| h.is_finished());
        for ans in reg.drain(done) {
            if ans.model != "churn" {
                answers.push(ans);
            }
        }
    }
    for h in pushers {
        h.join().unwrap();
    }
    churner.join().unwrap();
    let _ = std::fs::remove_file(&churn_path);

    // Every answer equals its solo-serving reference, bit for bit —
    // tenant mix, shared pool, churn, and batch padding included.
    assert_eq!(answers.len(), total);
    let mut seen = vec![false; total];
    for ans in &answers {
        let ti = tenants.iter().position(|(id, _)| *id == ans.model).unwrap();
        let k = ans.request as usize - ti * n_each;
        assert!(!seen[ans.request as usize], "duplicate answer {}", ans.request);
        seen[ans.request as usize] = true;
        let reference = &expected[ti][k];
        assert_eq!(ans.logits.len(), reference.len());
        for (i, (&u, &v)) in ans.logits.iter().zip(reference).enumerate() {
            assert_eq!(
                u.to_bits(),
                v.to_bits(),
                "{}#{k} logit {i} differs from solo serving",
                ans.model
            );
        }
    }
    assert!(seen.iter().all(|&s| s), "every request answered exactly once");
}
