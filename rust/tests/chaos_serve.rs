//! Chaos suite: overload, deadline shedding, injected panics, and
//! forced decode failures driven against the multi-tenant registry
//! through the deterministic `obs::faultpoint` harness.
//!
//! The contract under fault is the same as the contract under load:
//! the process never aborts, queues never exceed their capacity, every
//! accepted request is accounted for (completed, failed, or shed —
//! never silently dropped), and tenants that a fault does *not* target
//! keep serving **bitwise identically** to solo serving on the shared
//! pool.
//!
//! Faultpoint state is process-global, so every test here serializes on
//! one mutex (the same discipline the unit tests in
//! `src/obs/faultpoint.rs` use).
//!
//! CI's chaos smoke step re-runs this binary with a non-trivial
//! `FAULT_PLAN` armed from the environment (see
//! `env_fault_plan_holds_generic_invariants`).

use std::sync::{Arc, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use lfsr_prune::data::rng::Pcg32;
use lfsr_prune::obs::faultpoint::{self, points};
use lfsr_prune::obs::{FaultAction, FaultPlan};
use lfsr_prune::serve::{synthetic_lenet300_seeded, CompiledModel, InferenceSession};
use lfsr_prune::store::{
    export_model, LoadOptions, ModelRegistry, RegistryError, StoreError, TenantConfig,
};

/// One mutex for the whole binary: plans are global.
static SERIAL: Mutex<()> = Mutex::new(());

fn serial() -> MutexGuard<'static, ()> {
    SERIAL.lock().unwrap_or_else(|e| e.into_inner())
}

/// A 1-shard-per-layer model: exactly one `session.shard` hit per layer
/// per inference attempt, so hit-window scripts are deterministic even
/// on a threaded pool.
fn chaos_model(seed: u32) -> CompiledModel {
    synthetic_lenet300_seeded(0.9, 1, 1, seed)
}

/// Deterministic per-request input, independent of push order.
fn request_input(dim: usize, id: u64) -> Vec<f32> {
    let mut rng = Pcg32::new(0xC4A05 ^ id);
    (0..dim).map(|_| rng.next_normal()).collect()
}

fn cfg(batch: usize, max_queue: usize) -> TenantConfig {
    TenantConfig {
        batch,
        max_wait: None,
        span_sample_every: 1,
        max_queue,
        // Chaos tests probe the breaker immediately; production keeps a
        // real backoff.
        breaker_backoff: Duration::ZERO,
    }
}

/// Answers for `model` drained to completion, with a stall guard.
fn drain_all(reg: &ModelRegistry, expect: usize) -> Vec<lfsr_prune::store::Answer> {
    let mut answers = Vec::new();
    let t0 = Instant::now();
    while answers.len() < expect {
        assert!(t0.elapsed() < Duration::from_secs(30), "drain stalled");
        answers.extend(reg.drain(true));
    }
    answers
}

#[test]
fn overload_past_capacity_is_bounded_typed_and_exactly_counted() {
    let _s = serial();
    faultpoint::disarm();
    let reg = ModelRegistry::new(2);
    let model = chaos_model(11);
    let dim = model.in_dim();
    reg.insert("m", model, cfg(2, 4)).unwrap();

    let offered = 16u64;
    let mut accepted = 0u64;
    let mut rejected = 0u64;
    for id in 0..offered {
        match reg.push("m", id, request_input(dim, id)) {
            Ok(()) => accepted += 1,
            Err(RegistryError::Overloaded { depth, capacity, .. }) => {
                assert_eq!((depth, capacity), (4, 4), "refused exactly at the bound");
                rejected += 1;
            }
            Err(e) => panic!("unexpected error: {e}"),
        }
        assert!(reg.pending() <= 4, "queue must never exceed max_queue");
    }
    assert_eq!(accepted, 4, "capacity admits exactly max_queue requests");
    assert_eq!(accepted + rejected, offered, "no request unaccounted");

    let answers = drain_all(&reg, accepted as usize);
    assert_eq!(answers.len(), accepted as usize);
    let s = reg.stats("m").unwrap();
    assert_eq!(s.overloaded, rejected);
    assert_eq!(s.requests, accepted, "every accepted push is counted as offered");
    assert_eq!(s.completed, accepted, "and every accepted push was answered");
    let text = reg.metrics_text();
    assert!(text.contains("serve_overload_total{model=\"m\"} 12\n"), "{text}");
}

#[test]
fn expired_deadlines_shed_before_compute_not_served_late() {
    let _s = serial();
    faultpoint::disarm();
    let reg = ModelRegistry::new(2);
    let model = chaos_model(13);
    let dim = model.in_dim();
    reg.insert("m", model, cfg(4, 64)).unwrap();

    let past = Instant::now() - Duration::from_millis(1);
    let future = Instant::now() + Duration::from_secs(120);
    reg.push_with_deadline("m", 0, request_input(dim, 0), Some(past)).unwrap();
    reg.push("m", 1, request_input(dim, 1)).unwrap();
    reg.push_with_deadline("m", 2, request_input(dim, 2), Some(past)).unwrap();
    reg.push_with_deadline("m", 3, request_input(dim, 3), Some(future)).unwrap();

    let answers = reg.drain(true);
    let mut ids: Vec<u64> = answers.iter().map(|a| a.request).collect();
    ids.sort_unstable();
    assert_eq!(ids, vec![1, 3], "expired requests never reach the pool");
    let s = reg.stats("m").unwrap();
    assert_eq!(s.shed, 2);
    assert_eq!(s.requests, 4, "all four pushes were accepted");
    assert_eq!(s.completed, 2, "only live requests completed");
    assert_eq!(s.batches, 1, "no compute was spent on the shed rows");
    let text = reg.metrics_text();
    assert!(text.contains("serve_shed_total{model=\"m\"} 2\n"), "{text}");
}

#[test]
fn injected_panic_quarantines_one_tenant_and_neighbors_stay_bitwise() {
    let _s = serial();
    let chaos = chaos_model(17);
    let quiet = chaos_model(23);
    let dim = chaos.in_dim();
    let n_each = 4usize;

    // Ground truth for the quiet tenant, computed alone.
    let solo = InferenceSession::new(quiet.clone(), 1);
    let expected: Vec<Vec<f32>> =
        (0..n_each).map(|id| solo.infer_one(&request_input(dim, id as u64))).collect();

    let reg = ModelRegistry::new(2);
    reg.insert("chaos-a", chaos, cfg(n_each, 64)).unwrap();
    reg.insert("quiet-b", quiet, cfg(n_each, 64)).unwrap();

    // Panic on the very first chaos-a shard execution, then relent.
    let plan = FaultPlan::seeded(7).with(
        points::SESSION_SHARD,
        Some("chaos-a"),
        FaultAction::Panic,
        1,
        1,
    );
    let _g = faultpoint::arm(&plan);

    for id in 0..n_each as u64 {
        reg.push("chaos-a", id, request_input(dim, id)).unwrap();
        reg.push("quiet-b", 100 + id, request_input(dim, id)).unwrap();
    }

    // First drain: chaos-a's batch dies to the injected panic (the
    // process does not), quiet-b's batch completes bitwise.
    let answers = reg.drain(true);
    assert!(
        answers.iter().all(|a| a.model == "quiet-b"),
        "the faulted tenant must produce no answers"
    );
    assert_eq!(answers.len(), n_each);
    for ans in &answers {
        let reference = &expected[(ans.request - 100) as usize];
        for (i, (&u, &v)) in ans.logits.iter().zip(reference).enumerate() {
            assert_eq!(
                u.to_bits(),
                v.to_bits(),
                "quiet-b#{} logit {i} differs from solo serving under fault",
                ans.request
            );
        }
    }
    let health: std::collections::BTreeMap<String, bool> =
        reg.list().into_iter().map(|m| (m.id, m.healthy)).collect();
    assert!(!health["chaos-a"], "panicking tenant is quarantined");
    assert!(health["quiet-b"], "neighbor stays healthy");
    let s = reg.stats("chaos-a").unwrap();
    assert_eq!(s.failed, n_each as u64, "the whole micro-batch failed");
    let text = reg.metrics_text();
    assert!(text.contains("serve_tenant_healthy{model=\"chaos-a\"} 0\n"), "{text}");
    assert!(text.contains("serve_tenant_healthy{model=\"quiet-b\"} 1\n"), "{text}");
    assert!(text.contains("serve_failed_total{model=\"chaos-a\"} 4\n"), "{text}");

    // Recovery: zero backoff means the next drain admits a half-open
    // probe; the fault window is spent, so the probe succeeds and the
    // tenant is healthy again — bitwise, like nothing happened.
    let solo_chaos = InferenceSession::new(chaos_model(17), 1);
    for id in 0..n_each as u64 {
        reg.push("chaos-a", 200 + id, request_input(dim, id)).unwrap();
    }
    let recovered = drain_all(&reg, n_each);
    assert!(recovered.iter().all(|a| a.model == "chaos-a"));
    for ans in &recovered {
        let reference = solo_chaos.infer_one(&request_input(dim, ans.request - 200));
        for (i, (&u, &v)) in ans.logits.iter().zip(&reference).enumerate() {
            assert_eq!(u.to_bits(), v.to_bits(), "recovered logit {i} must be bitwise");
        }
    }
    assert!(reg.list().iter().all(|m| m.healthy), "probe success restores Healthy");
    let text = reg.metrics_text();
    assert!(text.contains("serve_tenant_healthy{model=\"chaos-a\"} 1\n"), "{text}");
}

#[test]
fn breaker_walks_healthy_unhealthy_halfopen_restored_on_script() {
    let _s = serial();
    // The ISSUE-scripted plan: panic on hits 1..=3, succeed on 4.  The
    // 1-shard 3-layer model fires once per layer, and a panic aborts the
    // attempt at the layer that fired it, so attempts 1-3 consume
    // exactly hits 1-3 and attempt 4 runs hits 4-6 clean.
    let plan =
        FaultPlan::seeded(7).with(points::SESSION_SHARD, Some("m"), FaultAction::Panic, 1, 3);
    let _g = faultpoint::arm(&plan);

    let reg = ModelRegistry::new(2);
    let model = chaos_model(29);
    let dim = model.in_dim();
    reg.insert("m", model, cfg(1, 64)).unwrap();

    let healthy = |reg: &ModelRegistry| reg.list().pop().unwrap().healthy;
    assert!(healthy(&reg), "starts Healthy");

    for attempt in 1..=3u64 {
        reg.push("m", attempt, request_input(dim, attempt)).unwrap();
        let answers = reg.drain(true);
        assert!(answers.is_empty(), "attempt {attempt} must die to the injected panic");
        assert!(!healthy(&reg), "attempt {attempt} leaves the tenant quarantined");
        assert_eq!(reg.stats("m").unwrap().failed, attempt, "one failed request per probe");
    }
    assert_eq!(faultpoint::hits(points::SESSION_SHARD), 3);

    // Fourth probe: the plan relents, the half-open probe succeeds.
    reg.push("m", 4, request_input(dim, 4)).unwrap();
    let answers = drain_all(&reg, 1);
    assert_eq!(answers[0].request, 4);
    assert!(healthy(&reg), "probe success restores Healthy");
    let s = reg.stats("m").unwrap();
    assert_eq!((s.failed, s.completed), (3, 1));
    assert_eq!(s.requests, 4, "all four probes were offered and accepted");
}

#[test]
fn quarantined_tenant_refuses_batches_until_backoff_elapses() {
    let _s = serial();
    let plan =
        FaultPlan::seeded(7).with(points::SESSION_SHARD, Some("m"), FaultAction::Panic, 1, 1);
    let _g = faultpoint::arm(&plan);

    let reg = ModelRegistry::new(2);
    let model = chaos_model(31);
    let dim = model.in_dim();
    // A real (but short) backoff this time: drains inside the window
    // must not even cut a batch.
    reg.insert(
        "m",
        model,
        TenantConfig { breaker_backoff: Duration::from_millis(150), ..cfg(1, 64) },
    )
    .unwrap();

    reg.push("m", 1, request_input(dim, 1)).unwrap();
    assert!(reg.drain(true).is_empty(), "first batch dies to the panic");
    reg.push("m", 2, request_input(dim, 2)).unwrap();

    // Inside the backoff window: the breaker refuses to cut, the queued
    // request neither completes nor fails.
    let t0 = Instant::now();
    let mut refused_at_least_once = false;
    while t0.elapsed() < Duration::from_millis(60) {
        assert!(reg.drain(true).is_empty());
        refused_at_least_once = true;
    }
    assert!(refused_at_least_once);
    assert_eq!(reg.pending(), 1, "request 2 stays queued while quarantined");
    assert_eq!(reg.stats("m").unwrap().failed, 1, "request 2 was not failed");

    // Past the backoff: the half-open probe runs (fault window is
    // spent) and request 2 is finally answered.
    std::thread::sleep(Duration::from_millis(150));
    let answers = drain_all(&reg, 1);
    assert_eq!(answers[0].request, 2);
    assert!(reg.list().pop().unwrap().healthy);
}

#[test]
fn forced_decode_failure_is_typed_and_the_next_load_succeeds() {
    let _s = serial();
    let path = std::env::temp_dir()
        .join(format!("lfsrpack_chaos_{}.lfsrpack", std::process::id()));
    export_model(&chaos_model(37), &path, 1).expect("export");

    let plan = FaultPlan::seeded(7).with(points::STORE_DECODE, None, FaultAction::Fail, 1, 1);
    let _g = faultpoint::arm(&plan);

    let reg = ModelRegistry::new(2);
    let opts = LoadOptions { n_shards: 1, lanes: 1, verify: false, precision: None };
    let err = reg.load("m", &path, &opts, cfg(2, 64)).unwrap_err();
    assert!(
        matches!(&err, RegistryError::Store(StoreError::Corrupt { detail })
            if detail.contains("faultpoint")),
        "forced decode failure must surface as the typed corrupt error, got {err}"
    );
    assert!(reg.is_empty(), "a failed load registers nothing");

    // Hit 2 is outside the window: the identical load now succeeds and
    // the tenant serves.
    reg.load("m", &path, &opts, cfg(2, 64)).unwrap();
    let dim = 784;
    reg.push("m", 0, request_input(dim, 0)).unwrap();
    reg.push("m", 1, request_input(dim, 1)).unwrap();
    assert_eq!(drain_all(&reg, 2).len(), 2);
    let _ = std::fs::remove_file(&path);
}

#[test]
fn admission_accounting_is_exact_under_8_thread_contention() {
    let _s = serial();
    faultpoint::disarm();
    const THREADS: u64 = 8;
    const PER_THREAD: u64 = 2_000;
    const CAPACITY: usize = 64;

    let reg = Arc::new(ModelRegistry::new(2));
    let model = chaos_model(41);
    let dim = model.in_dim();
    reg.insert("m", model, cfg(32, CAPACITY)).unwrap();

    // No drain while pushing: every accepted request stays queued, so
    // accepted == pending at the end and the books must balance exactly
    // (the same exactness bar obs_metrics.rs sets for raw counters).
    let x = request_input(dim, 0);
    let handles: Vec<_> = (0..THREADS)
        .map(|t| {
            let reg = Arc::clone(&reg);
            let x = x.clone();
            std::thread::spawn(move || {
                let mut accepted = 0u64;
                for k in 0..PER_THREAD {
                    match reg.push("m", t * PER_THREAD + k, x.clone()) {
                        Ok(()) => accepted += 1,
                        Err(RegistryError::Overloaded { depth, capacity, .. }) => {
                            assert_eq!(capacity, CAPACITY);
                            assert!(depth >= CAPACITY, "refused only at (or past) the bound");
                        }
                        Err(e) => panic!("unexpected error: {e}"),
                    }
                }
                accepted
            })
        })
        .collect();
    let accepted: u64 = handles.into_iter().map(|h| h.join().unwrap()).sum();

    assert!(reg.pending() <= CAPACITY, "queue never exceeds capacity");
    assert_eq!(reg.pending() as u64, accepted, "every accepted request is queued");
    let s = reg.stats("m").unwrap();
    // `requests` now reports pushes directly (it used to alias the
    // completion counter, forcing this test to scrape the exposition).
    assert_eq!(s.requests, accepted);
    assert_eq!(s.completed, 0, "nothing drained, nothing completed");
    assert_eq!(
        s.requests + s.overloaded,
        THREADS * PER_THREAD,
        "accepted + refused must account for every offered request"
    );
}

#[test]
fn env_fault_plan_holds_generic_invariants() {
    let _s = serial();
    // CI arms a real plan via FAULT_PLAN; locally this falls back to a
    // representative one.  Whatever the (bounded) plan, the invariants
    // below must hold: the process survives, queues stay bounded, and
    // accepted requests are all accounted for.
    let plan = match FaultPlan::from_env().expect("FAULT_PLAN must parse") {
        Some(p) => p,
        None => FaultPlan::parse(
            "seed=7;session.shard[chaos-a]=panic@1..2;store.decode=fail@1;pool.task=delay:1@1..4",
        )
        .unwrap(),
    };
    let _g = faultpoint::arm(&plan);

    let reg = ModelRegistry::new(2);
    let chaos = chaos_model(43);
    let dim = chaos.in_dim();
    const CAP: usize = 8;
    reg.insert("chaos-a", chaos, cfg(2, CAP)).unwrap();
    reg.insert("quiet-b", chaos_model(47), cfg(2, CAP)).unwrap();

    let mut accepted = [0u64; 2];
    let mut refused = [0u64; 2];
    let t0 = Instant::now();
    for round in 0..12u64 {
        for (ti, id) in ["chaos-a", "quiet-b"].into_iter().enumerate() {
            for k in 0..4u64 {
                match reg.push(id, round * 100 + k, request_input(dim, k)) {
                    Ok(()) => accepted[ti] += 1,
                    Err(RegistryError::Overloaded { .. }) => refused[ti] += 1,
                    Err(e) => panic!("unexpected error: {e}"),
                }
            }
            assert!(
                reg.list().iter().all(|m| m.pending <= CAP),
                "queues must stay bounded under chaos"
            );
        }
        reg.drain(true);
        assert!(t0.elapsed() < Duration::from_secs(30), "chaos drain stalled");
    }
    // Let quarantined tenants recover (bounded plans relent; zero
    // backoff makes every drain a probe) and flush the queues.
    let t1 = Instant::now();
    while reg.pending() > 0 {
        assert!(t1.elapsed() < Duration::from_secs(30), "recovery stalled");
        reg.drain(true);
    }
    for (ti, id) in ["chaos-a", "quiet-b"].into_iter().enumerate() {
        let s = reg.stats(id).unwrap();
        assert_eq!(s.requests, accepted[ti], "{id}: offered == accepted pushes");
        assert_eq!(
            s.completed + s.failed + s.shed,
            accepted[ti],
            "{id}: every accepted request completed, failed, or shed — none lost"
        );
        assert_eq!(s.overloaded, refused[ti], "{id}: refusals counted exactly");
    }
    assert!(
        reg.list().iter().all(|m| m.healthy),
        "all tenants recovered once the plan relented"
    );
}
