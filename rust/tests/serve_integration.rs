//! Integration: the batched serving engine against every other way the
//! repo computes a masked forward pass.
//!
//! * batched == sequential single-request execution, bit-for-bit, with
//!   worker count > 1 and partial (padded) final batches — PRS,
//!   magnitude, and random masks;
//! * serve single-layer matvec == `hw::lfsr_engine` cycle engine,
//!   bit-for-bit (same walk order ⇒ same float accumulation order);
//! * parallel jump-table walk replay == `mask::prs::prs_keep_sequence`;
//! * serve forward ≈ `runtime::ModelRunner::forward` through the AOT
//!   artifacts (skipped gracefully when `make artifacts` has not run).

use lfsr_prune::data::rng::Pcg32;
use lfsr_prune::hw::{lfsr_engine, Mode, SparseLayer};
use lfsr_prune::mask::prs::{prs_keep_sequence, prs_mask, PrsMaskConfig};
use lfsr_prune::mask::{magnitude_mask, random_mask, Mask};
use lfsr_prune::serve::{
    parallel_keep_sequence, Batcher, CompiledLayer, CompiledModel, InferenceSession,
};

const D0: usize = 48;
const D1: usize = 32;
const D2: usize = 10;

fn weights(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Pcg32::new(seed);
    (0..n).map(|_| rng.next_normal()).collect()
}

/// Two-layer model with one mask method applied to both layers.
fn model_for(method: &str, shards: usize) -> CompiledModel {
    let w1 = weights(D0 * D1, 10);
    let w2 = weights(D1 * D2, 11);
    let b1 = weights(D1, 12);
    let b2 = weights(D2, 13);
    let layer = |w: &[f32], b: Vec<f32>, relu: bool, rows: usize, cols: usize, salt: u32| {
        match method {
            "prs" => {
                let cfg = PrsMaskConfig::auto(rows, cols, 3 + salt, 7 + salt);
                CompiledLayer::compile_prs(w, b, relu, rows, cols, 0.8, cfg, shards, 2)
            }
            "magnitude" => {
                let m = magnitude_mask(rows, cols, w, 0.8);
                CompiledLayer::from_mask(w, b, relu, &m, shards)
            }
            "random" => {
                let m = random_mask(rows, cols, 0.8, 99 + salt as u64);
                CompiledLayer::from_mask(w, b, relu, &m, shards)
            }
            other => panic!("unknown method {other}"),
        }
    };
    CompiledModel::new(vec![
        layer(&w1, b1, true, D0, D1, 0),
        layer(&w2, b2, false, D1, D2, 1),
    ])
}

#[test]
fn batched_equals_sequential_all_mask_methods() {
    let batch = 7;
    let x = weights(batch * D0, 21);
    for method in ["prs", "magnitude", "random"] {
        let session = InferenceSession::new(model_for(method, 4), 4);
        assert!(session.workers() > 1, "parity must hold under real threading");
        let all = session.infer_batch(&x, batch);
        assert_eq!(all.len(), batch * D2);
        for b in 0..batch {
            let one = session.infer_one(&x[b * D0..(b + 1) * D0]);
            for k in 0..D2 {
                assert_eq!(
                    all[b * D2 + k].to_bits(),
                    one[k].to_bits(),
                    "{method}: row {b} logit {k}"
                );
            }
        }
    }
}

#[test]
fn partial_final_batch_parity_through_batcher() {
    // 11 requests at batch 4: three cuts, the last one padded 3+1.
    let session = InferenceSession::new(model_for("prs", 3), 3);
    let n = 11usize;
    let batch = 4usize;
    let xs = weights(n * D0, 33);
    let mut batcher = Batcher::new(batch, D0);
    for i in 0..n {
        batcher.push(i as u64, xs[i * D0..(i + 1) * D0].to_vec());
    }
    let mut answered = vec![Vec::new(); n];
    let mut cuts = 0;
    while let Some(mb) = batcher.next_batch(true) {
        let logits = session.infer_batch(&mb.x, mb.batch);
        for (row, &id) in mb.ids.iter().enumerate() {
            answered[id as usize] = logits[row * D2..(row + 1) * D2].to_vec();
        }
        batcher.complete(mb);
        cuts += 1;
    }
    assert_eq!(cuts, 3);
    let stats = batcher.stats();
    assert_eq!(stats.requests, n as u64);
    assert_eq!(stats.padded, (batch - n % batch) as u64);
    // Every request's answer equals its standalone single-request answer,
    // padded batch included.
    for i in 0..n {
        let one = session.infer_one(&xs[i * D0..(i + 1) * D0]);
        for k in 0..D2 {
            assert_eq!(answered[i][k].to_bits(), one[k].to_bits(), "req {i} logit {k}");
        }
    }
}

#[test]
fn serve_matvec_bitwise_matches_cycle_engine() {
    // Single layer, no bias/relu, batch 1: the serving GEMM and the
    // hw cycle engine accumulate each output column in the same walk
    // order, so the floats must agree bit-for-bit.
    let (rows, cols, sp) = (100, 80, 0.7);
    let cfg = PrsMaskConfig::auto(rows, cols, 5, 11);
    let w = weights(rows * cols, 41);
    let x = weights(rows, 42);
    let mask = prs_mask(rows, cols, sp, cfg);
    let engine_out = lfsr_engine::run(
        &SparseLayer {
            rows,
            cols,
            weights: w.clone(),
            mask,
            input: x.clone(),
        },
        cfg,
        Mode::Ideal,
    )
    .output;
    let layer = CompiledLayer::compile_prs(&w, Vec::new(), false, rows, cols, sp, cfg, 5, 3);
    let session = InferenceSession::new(CompiledModel::new(vec![layer]), 2);
    let serve_out = session.infer_one(&x);
    assert_eq!(serve_out.len(), engine_out.len());
    for c in 0..cols {
        assert_eq!(serve_out[c].to_bits(), engine_out[c].to_bits(), "col {c}");
    }
}

#[test]
fn parallel_walk_replay_is_pinned_to_serial_walk() {
    // 784x300@0.9 (the demo model's first layer) has an expected walk of
    // ~25k raw steps — enough that the jump-table lanes really run.
    for (rows, cols, sp) in [(30, 20, 0.8), (64, 64, 0.95), (300, 100, 0.9), (784, 300, 0.9)] {
        let cfg = PrsMaskConfig::auto(rows, cols, 17, 23);
        let serial = prs_keep_sequence(rows, cols, sp, cfg);
        for lanes in [1usize, 2, 5] {
            let par = parallel_keep_sequence(rows, cols, sp, cfg, lanes);
            assert_eq!(par, serial, "{rows}x{cols}@{sp} lanes={lanes}");
        }
    }
}

#[test]
fn dense_serve_matches_host_matmul() {
    // Dense mask sanity: serving reduces to plain x·W + b with relu.
    let (rows, cols, batch) = (9, 6, 2);
    let w = weights(rows * cols, 51);
    let b = weights(cols, 52);
    let x = weights(batch * rows, 53);
    let layer = CompiledLayer::from_mask(&w, b.clone(), true, &Mask::dense(rows, cols), 2);
    let session = InferenceSession::new(CompiledModel::new(vec![layer]), 2);
    let y = session.infer_batch(&x, batch);
    for bi in 0..batch {
        for c in 0..cols {
            let mut acc = 0.0f32;
            for r in 0..rows {
                acc += x[bi * rows + r] * w[r * cols + c];
            }
            acc = (acc + b[c]).max(0.0);
            assert!((y[bi * cols + c] - acc).abs() < 1e-4, "({bi},{c})");
        }
    }
}

// ---------------------------------------------------------------------------
// Artifact-gated parity vs the PJRT runtime (skips without `make artifacts`)
// ---------------------------------------------------------------------------

#[test]
fn serve_matches_model_runner_forward() {
    use lfsr_prune::runtime::{ModelRunner, Runtime, Tensor};

    let dir = Runtime::default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: no artifacts at {dir:?} — run `make artifacts`");
        return;
    }
    let rt = Runtime::new(dir).expect("runtime");
    let runner = ModelRunner::new(&rt, "lenet300").expect("lenet300");
    let params = runner.init_params(5);
    let midx = runner.maskable_indices();

    // PRS masks for the runtime, same seeds for the serve compile; each
    // weight's bias is the matching `*_b` parameter (zeros if absent).
    let mut masks = runner.dense_masks();
    let mut serve_layers = Vec::new();
    for (i, &pi) in midx.iter().enumerate() {
        let shape = runner.man.params[pi].shape.clone();
        let cfg = PrsMaskConfig::auto(shape[0], shape[1], 11 + i as u32, 29 + i as u32);
        let m = prs_mask(shape[0], shape[1], 0.9, cfg);
        masks[i] = Tensor::f32(shape.clone(), m.to_f32());
        let w = params[pi].as_f32().to_vec();
        let wname = &runner.man.params[pi].name;
        let bias = runner
            .man
            .params
            .iter()
            .position(|p| p.name == wname.replace("_w", "_b"))
            .map(|bi| params[bi].as_f32().to_vec())
            .unwrap_or_default();
        let last = i + 1 == midx.len();
        serve_layers.push(CompiledLayer::compile_prs(
            &w,
            bias,
            !last,
            shape[0],
            shape[1],
            0.9,
            cfg,
            4,
            2,
        ));
    }
    let session = InferenceSession::new(CompiledModel::new(serve_layers), 3);

    let batch = runner.man.batch.min(8);
    let x = weights(batch * session.model().in_dim(), 61);
    let native = session.infer_batch(&x, batch);
    let xla_out = runner
        .forward_padded(&params, &masks, &x, batch)
        .expect("artifact forward");
    let xla = xla_out.as_f32();
    assert_eq!(xla.len(), native.len());
    for (i, (&a, &b)) in native.iter().zip(xla).enumerate() {
        assert!(
            (a - b).abs() < 1e-3 * (1.0 + a.abs().max(b.abs())),
            "logit {i}: native {a} vs artifact {b}"
        );
    }
}
