//! Integration: the batched serving engine against every other way the
//! repo computes a masked forward pass.
//!
//! * batched == sequential single-request execution, bit-for-bit, with
//!   worker count > 1 and partial (padded) final batches — PRS,
//!   magnitude, and random masks;
//! * serve single-layer matvec == `hw::lfsr_engine` cycle engine,
//!   bit-for-bit (same walk order ⇒ same float accumulation order);
//! * parallel jump-table walk replay == `mask::prs::prs_keep_sequence`;
//! * serve forward ≈ `runtime::ModelRunner::forward` through the AOT
//!   artifacts (skipped gracefully when `make artifacts` has not run).

use lfsr_prune::data::rng::Pcg32;
use lfsr_prune::hw::{lfsr_engine, Mode, SparseLayer};
use lfsr_prune::mask::prs::{prs_keep_sequence, prs_mask, PrsMaskConfig};
use lfsr_prune::mask::{magnitude_mask, random_mask, Mask};
use lfsr_prune::serve::{
    parallel_keep_sequence, synthetic_lenet300, Batcher, CompiledLayer, CompiledModel,
    InferenceSession,
};
use lfsr_prune::sparse::{transpose_panels, ConvGeom, KernelPath, PoolGeom, BATCH_LANES};
use lfsr_prune::store::format::hash_keep_sequence;

const D0: usize = 48;
const D1: usize = 32;
const D2: usize = 10;

fn weights(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Pcg32::new(seed);
    (0..n).map(|_| rng.next_normal()).collect()
}

/// Two-layer model with one mask method applied to both layers.
fn model_for(method: &str, shards: usize) -> CompiledModel {
    let w1 = weights(D0 * D1, 10);
    let w2 = weights(D1 * D2, 11);
    let b1 = weights(D1, 12);
    let b2 = weights(D2, 13);
    let layer = |w: &[f32], b: Vec<f32>, relu: bool, rows: usize, cols: usize, salt: u32| {
        match method {
            "prs" => {
                let cfg = PrsMaskConfig::auto(rows, cols, 3 + salt, 7 + salt);
                CompiledLayer::compile_prs(w, b, relu, rows, cols, 0.8, cfg, shards, 2)
            }
            "magnitude" => {
                let m = magnitude_mask(rows, cols, w, 0.8);
                CompiledLayer::from_mask(w, b, relu, &m, shards)
            }
            "random" => {
                let m = random_mask(rows, cols, 0.8, 99 + salt as u64);
                CompiledLayer::from_mask(w, b, relu, &m, shards)
            }
            other => panic!("unknown method {other}"),
        }
    };
    CompiledModel::new(vec![
        layer(&w1, b1, true, D0, D1, 0),
        layer(&w2, b2, false, D1, D2, 1),
    ])
}

#[test]
fn batched_equals_sequential_all_mask_methods() {
    let batch = 7;
    let x = weights(batch * D0, 21);
    for method in ["prs", "magnitude", "random"] {
        let session = InferenceSession::new(model_for(method, 4), 4);
        assert!(session.workers() > 1, "parity must hold under real threading");
        let all = session.infer_batch(&x, batch);
        assert_eq!(all.len(), batch * D2);
        for b in 0..batch {
            let one = session.infer_one(&x[b * D0..(b + 1) * D0]);
            for k in 0..D2 {
                assert_eq!(
                    all[b * D2 + k].to_bits(),
                    one[k].to_bits(),
                    "{method}: row {b} logit {k}"
                );
            }
        }
    }
}

#[test]
fn partial_final_batch_parity_through_batcher() {
    // 11 requests at batch 4: three cuts, the last one padded 3+1.
    let session = InferenceSession::new(model_for("prs", 3), 3);
    let n = 11usize;
    let batch = 4usize;
    let xs = weights(n * D0, 33);
    let mut batcher = Batcher::new(batch, D0);
    for i in 0..n {
        batcher.push(i as u64, xs[i * D0..(i + 1) * D0].to_vec());
    }
    let mut answered = vec![Vec::new(); n];
    let mut cuts = 0;
    while let Some(mb) = batcher.next_batch(true) {
        let logits = session.infer_batch(&mb.x, mb.batch);
        for (row, &id) in mb.ids.iter().enumerate() {
            answered[id as usize] = logits[row * D2..(row + 1) * D2].to_vec();
        }
        batcher.complete(mb);
        cuts += 1;
    }
    assert_eq!(cuts, 3);
    let stats = batcher.stats();
    assert_eq!(stats.requests, n as u64);
    assert_eq!(stats.padded, (batch - n % batch) as u64);
    // Every request's answer equals its standalone single-request answer,
    // padded batch included.
    for i in 0..n {
        let one = session.infer_one(&xs[i * D0..(i + 1) * D0]);
        for k in 0..D2 {
            assert_eq!(answered[i][k].to_bits(), one[k].to_bits(), "req {i} logit {k}");
        }
    }
}

#[test]
fn serve_matvec_bitwise_matches_cycle_engine() {
    // Single layer, no bias/relu, batch 1: the serving GEMM and the
    // hw cycle engine accumulate each output column in the same walk
    // order, so the floats must agree bit-for-bit.
    let (rows, cols, sp) = (100, 80, 0.7);
    let cfg = PrsMaskConfig::auto(rows, cols, 5, 11);
    let w = weights(rows * cols, 41);
    let x = weights(rows, 42);
    let mask = prs_mask(rows, cols, sp, cfg);
    let engine_out = lfsr_engine::run(
        &SparseLayer {
            rows,
            cols,
            weights: w.clone(),
            mask,
            input: x.clone(),
        },
        cfg,
        Mode::Ideal,
    )
    .output;
    let layer = CompiledLayer::compile_prs(&w, Vec::new(), false, rows, cols, sp, cfg, 5, 3);
    let mut session = InferenceSession::new(CompiledModel::new(vec![layer]), 2);
    // The cycle engine is the scalar op order — pin the session to the
    // scalar oracle so this stays bitwise under a SIMD process default.
    session.set_kernel_path(KernelPath::Scalar);
    let serve_out = session.infer_one(&x);
    assert_eq!(serve_out.len(), engine_out.len());
    for c in 0..cols {
        assert_eq!(serve_out[c].to_bits(), engine_out[c].to_bits(), "col {c}");
    }
}

#[test]
fn parallel_walk_replay_is_pinned_to_serial_walk() {
    // 784x300@0.9 (the demo model's first layer) has an expected walk of
    // ~25k raw steps — enough that the jump-table lanes really run.
    for (rows, cols, sp) in [(30, 20, 0.8), (64, 64, 0.95), (300, 100, 0.9), (784, 300, 0.9)] {
        let cfg = PrsMaskConfig::auto(rows, cols, 17, 23);
        let serial = prs_keep_sequence(rows, cols, sp, cfg);
        for lanes in [1usize, 2, 5] {
            let par = parallel_keep_sequence(rows, cols, sp, cfg, lanes);
            assert_eq!(par, serial, "{rows}x{cols}@{sp} lanes={lanes}");
        }
    }
}

#[test]
fn dense_serve_matches_host_matmul() {
    // Dense mask sanity: serving reduces to plain x·W + b with relu.
    let (rows, cols, batch) = (9, 6, 2);
    let w = weights(rows * cols, 51);
    let b = weights(cols, 52);
    let x = weights(batch * rows, 53);
    let layer = CompiledLayer::from_mask(&w, b.clone(), true, &Mask::dense(rows, cols), 2);
    let session = InferenceSession::new(CompiledModel::new(vec![layer]), 2);
    let y = session.infer_batch(&x, batch);
    for bi in 0..batch {
        for c in 0..cols {
            let mut acc = 0.0f32;
            for r in 0..rows {
                acc += x[bi * rows + r] * w[r * cols + c];
            }
            acc = (acc + b[c]).max(0.0);
            assert!((y[bi * cols + c] - acc).abs() < 1e-4, "({bi},{c})");
        }
    }
}

// ---------------------------------------------------------------------------
// Regression pins: the FC-only path is byte-identical across refactors
// ---------------------------------------------------------------------------

#[test]
fn lenet300_walk_and_packing_pinned() {
    // Constants generated by an exact integer-only python mirror of the
    // two-LFSR walk (cross-checked against ref.py's `lfsr_pair_mask`).
    // These pin the demo model's index derivation across refactors: if
    // any value moves, every artifact and every serving layout built
    // from these seeds has silently changed.
    type Pin = (usize, usize, u32, u32, usize, u64, (usize, usize), (usize, usize));
    const PINS: [Pin; 3] = [
        (784, 300, 12, 11, 23520, 0x8185_404f_420a_032a, (688, 189), (779, 243)),
        (300, 100, 11, 9, 3000, 0x9a58_95cc_909d_5509, (0, 2), (184, 82)),
        (100, 10, 9, 7, 100, 0x42bb_ec36_09d9_1b22, (54, 8), (56, 2)),
    ];
    for (i, &(rows, cols, n_row, n_col, nnz, hash, first, last)) in PINS.iter().enumerate() {
        let cfg = PrsMaskConfig::auto(rows, cols, 11 + i as u32, 29 + i as u32);
        assert_eq!((cfg.n_row, cfg.n_col), (n_row, n_col), "layer {i}: widths");
        let seq = parallel_keep_sequence(rows, cols, 0.9, cfg, 2);
        assert_eq!(seq.len(), nnz, "layer {i}: keep budget");
        assert_eq!(seq[0], first, "layer {i}: first kept position");
        assert_eq!(*seq.last().unwrap(), last, "layer {i}: last kept position");
        assert_eq!(hash_keep_sequence(&seq), hash, "layer {i}: walk hash");
    }
    // And the compiled demo model really is built from those walks.
    let model = synthetic_lenet300(0.9, 4, 2);
    assert_eq!(model.nnz(), 23520 + 3000 + 100);
}

#[test]
fn fc_session_path_byte_identical_to_manual_panel_reference() {
    // The conv-plane refactor must not perturb FC serving by a single
    // bit: replay the pre-refactor op sequence by hand from the sparse
    // primitives (transpose -> per-shard panel GEMM -> ping-pong) and
    // compare the session's logits bitwise — padded tail panels, bias
    // skipping, ReLU, shard offsets and all.
    let model = synthetic_lenet300(0.9, 5, 2);
    for workers in [1usize, 3] {
        let session = InferenceSession::new(model.clone(), workers);
        for batch in [1usize, 9] {
            let x = weights(batch * 784, 90 + batch as u64);
            let mut a = x.clone();
            let mut panels = Vec::new();
            for layer in &model.layers {
                transpose_panels(&a, batch, layer.rows, &mut panels);
                let mut out = vec![0.0f32; batch * layer.cols];
                let slab = layer.rows * BATCH_LANES;
                let n_panels = batch.div_ceil(BATCH_LANES);
                for shard in &layer.shards {
                    for p in 0..n_panels {
                        let lanes = (batch - p * BATCH_LANES).min(BATCH_LANES);
                        shard.gemm_panel_into(
                            &panels[p * slab..][..slab],
                            lanes,
                            &layer.bias,
                            layer.relu,
                            &mut out[p * BATCH_LANES * layer.cols..],
                            layer.cols,
                        );
                    }
                }
                a = out;
            }
            let got = session.infer_batch(&x, batch);
            assert_eq!(got.len(), a.len());
            for (i, (&u, &v)) in got.iter().zip(&a).enumerate() {
                assert_eq!(u.to_bits(), v.to_bits(), "workers {workers} batch {batch} logit {i}");
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Artifact-gated parity vs the PJRT runtime (skips without `make artifacts`)
// ---------------------------------------------------------------------------

#[test]
fn serve_matches_model_runner_forward() {
    use lfsr_prune::runtime::{ModelRunner, Runtime, Tensor};

    let dir = Runtime::default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: no artifacts at {dir:?} — run `make artifacts`");
        return;
    }
    let rt = Runtime::new(dir).expect("runtime");
    let runner = ModelRunner::new(&rt, "lenet300").expect("lenet300");
    let params = runner.init_params(5);
    let midx = runner.maskable_indices();

    // PRS masks for the runtime, same seeds for the serve compile; each
    // weight's bias is the matching `*_b` parameter (zeros if absent).
    let mut masks = runner.dense_masks();
    let mut serve_layers = Vec::new();
    for (i, &pi) in midx.iter().enumerate() {
        let shape = runner.man.params[pi].shape.clone();
        let cfg = PrsMaskConfig::auto(shape[0], shape[1], 11 + i as u32, 29 + i as u32);
        let m = prs_mask(shape[0], shape[1], 0.9, cfg);
        masks[i] = Tensor::f32(shape.clone(), m.to_f32());
        let w = params[pi].as_f32().to_vec();
        let wname = &runner.man.params[pi].name;
        let bias = runner
            .man
            .params
            .iter()
            .position(|p| p.name == wname.replace("_w", "_b"))
            .map(|bi| params[bi].as_f32().to_vec())
            .unwrap_or_default();
        let last = i + 1 == midx.len();
        serve_layers.push(CompiledLayer::compile_prs(
            &w,
            bias,
            !last,
            shape[0],
            shape[1],
            0.9,
            cfg,
            4,
            2,
        ));
    }
    let session = InferenceSession::new(CompiledModel::new(serve_layers), 3);

    let batch = runner.man.batch.min(8);
    let x = weights(batch * session.model().in_dim(), 61);
    let native = session.infer_batch(&x, batch);
    let xla_out = runner
        .forward_padded(&params, &masks, &x, batch)
        .expect("artifact forward");
    let xla = xla_out.as_f32();
    assert_eq!(xla.len(), native.len());
    for (i, (&a, &b)) in native.iter().zip(xla).enumerate() {
        assert!(
            (a - b).abs() < 1e-3 * (1.0 + a.abs().max(b.abs())),
            "logit {i}: native {a} vs artifact {b}"
        );
    }
}

#[test]
fn vgg16_serve_matches_model_runner_forward() {
    // The paper's flagship network end to end: build the conv-capable
    // serve model (dense 3x3 SAME convs + 2x2 pools + PRS-pruned FC
    // head) from the SAME params/masks the AOT vgg16 graph consumes, and
    // compare logits against `ModelRunner::forward`.  Skips without
    // `make artifacts`, like the lenet parity test above.
    use lfsr_prune::runtime::{ModelRunner, Runtime, Tensor};

    let dir = Runtime::default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: no artifacts at {dir:?} — run `make artifacts`");
        return;
    }
    let rt = Runtime::new(dir).expect("runtime");
    let runner = ModelRunner::new(&rt, "vgg16").expect("vgg16");
    let params = runner.init_params(7);
    let by_name = |name: &str| {
        runner
            .man
            .params
            .iter()
            .position(|p| p.name == name)
            .unwrap_or_else(|| panic!("vgg16 manifest has no param {name}"))
    };

    // Conv trunk: conv{i}_w are HWIO [k, k, in_c, out_c]; the python
    // graph pools after convs 1, 3, 6, 9 (the paper's eliminated fifth
    // pool never appears).  Input is NHWC [batch, 64, 64, 3].
    let shape_x = runner.man.batch_x_shape();
    let mut hw_dim = shape_x[1];
    let mut serve_layers = Vec::new();
    let mut ci = 0usize;
    while runner.man.params.iter().any(|p| p.name == format!("conv{ci}_w")) {
        let wi = by_name(&format!("conv{ci}_w"));
        let shape = runner.man.params[wi].shape.clone();
        let (k, in_c, out_c) = (shape[0], shape[2], shape[3]);
        let geom = ConvGeom {
            in_h: hw_dim,
            in_w: hw_dim,
            in_c,
            out_c,
            kernel: k,
            stride: 1,
            pad: (k - 1) / 2, // SAME for the odd kernels VGG uses
        };
        let w = params[wi].as_f32().to_vec();
        let bias = params[by_name(&format!("conv{ci}_b"))].as_f32().to_vec();
        serve_layers.push(CompiledLayer::conv_from_mask(
            &w,
            bias,
            true,
            &Mask::dense(geom.patch_len(), out_c),
            geom,
            4,
        ));
        if matches!(ci, 1 | 3 | 6 | 9) {
            serve_layers.push(CompiledLayer::maxpool(PoolGeom::pool2(hw_dim, hw_dim, out_c)));
            hw_dim /= 2;
        }
        ci += 1;
    }
    assert_eq!(ci, 13, "modified VGG-16 has 13 conv layers");

    // PRS-pruned FC head, masks fed to the runtime exactly as compiled.
    let midx = runner.maskable_indices();
    let mut masks = runner.dense_masks();
    for (i, &pi) in midx.iter().enumerate() {
        let shape = runner.man.params[pi].shape.clone();
        let cfg = PrsMaskConfig::auto(shape[0], shape[1], 11 + i as u32, 29 + i as u32);
        let m = prs_mask(shape[0], shape[1], 0.9, cfg);
        masks[i] = Tensor::f32(shape.clone(), m.to_f32());
        let w = params[pi].as_f32().to_vec();
        let wname = &runner.man.params[pi].name;
        let bias = params[by_name(&wname.replace("_w", "_b"))].as_f32().to_vec();
        let last = i + 1 == midx.len();
        serve_layers.push(CompiledLayer::compile_prs(
            &w,
            bias,
            !last,
            shape[0],
            shape[1],
            0.9,
            cfg,
            4,
            2,
        ));
    }
    let session = InferenceSession::new(CompiledModel::new(serve_layers), 3);
    let counts = session.model().layer_kind_counts();
    assert_eq!((counts.conv, counts.pool, counts.fc), (13, 4, 3));

    let batch = runner.man.batch.min(4);
    let x = weights(batch * session.model().in_dim(), 67);
    let native = session.infer_batch(&x, batch);
    let xla_out = runner
        .forward_padded(&params, &masks, &x, batch)
        .expect("artifact forward");
    let xla = xla_out.as_f32();
    assert_eq!(xla.len(), native.len());
    // Looser than the lenet bound: 13 conv layers of f32 accumulation in
    // different orders (im2col walk vs XLA's conv) legitimately drift.
    for (i, (&a, &b)) in native.iter().zip(xla).enumerate() {
        assert!(
            (a - b).abs() < 5e-3 * (1.0 + a.abs().max(b.abs())),
            "logit {i}: native {a} vs artifact {b}"
        );
    }
}
