//! The batcher's accounting is bounded and allocation-free under
//! traffic: 1M+ pushes through the push → cut → complete loop allocate
//! exactly one buffer per push (the caller's request vector) and
//! nothing else — the metric bundle's histograms absorb every latency
//! sample into fixed storage, and [`Batcher::stats`] derives its
//! summary in O(buckets) without cloning samples.  The old
//! `latencies_s: Vec<f64>` design fails this test twice over: its log
//! grew by 8 bytes per request forever, and every `stats()` call
//! cloned + sorted the whole log.
//!
//! This file deliberately holds ONE test: it installs
//! [`CountingAllocator`] as the binary's global allocator and asserts
//! an exact allocation count, so no sibling test may run (and allocate)
//! concurrently in this process.

use lfsr_prune::obs::CountingAllocator;
use lfsr_prune::serve::Batcher;

#[global_allocator]
static ALLOC: CountingAllocator = CountingAllocator;

const EXAMPLE_LEN: usize = 8;
const BATCH: usize = 64;
const PUSHES_PER_ROUND: usize = 256;
const ROUNDS: usize = 4096;
const WARMUP_ROUNDS: usize = 2;

fn run_round(b: &mut Batcher, round: usize) {
    for i in 0..PUSHES_PER_ROUND {
        // The one allocation this loop is allowed: the request payload,
        // owned by the caller by contract.
        let x = vec![0.25_f32; EXAMPLE_LEN];
        b.push((round * PUSHES_PER_ROUND + i) as u64, x).unwrap();
    }
    while let Some(mb) = b.next_batch(true) {
        b.complete(mb);
    }
    // Snapshotting stats every round is part of the measured region: it
    // must be O(buckets) reads, not a clone-and-sort of the sample log.
    let s = b.stats();
    assert_eq!(s.requests, ((round + 1) * PUSHES_PER_ROUND) as u64);
}

#[test]
fn million_pushes_allocate_one_buffer_per_push_and_nothing_else() {
    let mut b = Batcher::new(BATCH, EXAMPLE_LEN);
    // Warmup: the queue, the recycled micro-batch buffers, and the
    // histogram storage all reach steady-state capacity here.
    for round in 0..WARMUP_ROUNDS {
        run_round(&mut b, round);
    }

    let before = lfsr_prune::obs::total_allocations();
    for round in WARMUP_ROUNDS..ROUNDS {
        run_round(&mut b, round);
    }
    let allocs = lfsr_prune::obs::total_allocations() - before;

    let measured_rounds = (ROUNDS - WARMUP_ROUNDS) as u64;
    let expected = measured_rounds * PUSHES_PER_ROUND as u64;
    assert_eq!(
        allocs, expected,
        "steady-state rounds must allocate exactly the request payloads \
         ({expected}), measured {allocs}"
    );

    // And the accounting saw every one of the 1M+ requests — in fixed
    // histogram storage, not an ever-growing log.
    let total = (ROUNDS * PUSHES_PER_ROUND) as u64;
    assert_eq!(total, 1_048_576);
    let m = b.metrics();
    assert_eq!(m.completed.get(), total);
    assert_eq!(m.complete.count(), total);
    assert_eq!(m.enqueue.count(), total);
    assert_eq!(m.cut.count(), total / BATCH as u64);
    let s = b.stats().latency.expect("latency summary");
    assert_eq!(s.samples as u64, total);
    assert!(s.p99 >= s.p95 && s.p95 >= s.median && s.median >= s.min);
}
