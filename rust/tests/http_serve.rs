//! The HTTP front door over real loopback sockets: routing, the status
//! mapping of every typed rejection, hostile-peer parse behavior, and
//! chaos (injected socket resets, shard panics) — the README's
//! rejection table verified on the wire.
//!
//! Faultpoint state is process-global, so every test here serializes on
//! one mutex (the discipline `tests/chaos_serve.rs` set); the non-fault
//! tests take it too because an armed plan from a neighbor would fire
//! in *their* server's socket reads.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use lfsr_prune::data::rng::Pcg32;
use lfsr_prune::obs::faultpoint::{self, points};
use lfsr_prune::obs::{FaultAction, FaultPlan};
use lfsr_prune::serve::http::Limits;
use lfsr_prune::serve::{synthetic_lenet300_seeded, HttpServer, InferenceSession, ServerConfig};
use lfsr_prune::store::{ModelRegistry, TenantConfig};
use lfsr_prune::util::json::{self, Json};

static SERIAL: Mutex<()> = Mutex::new(());

fn serial() -> MutexGuard<'static, ()> {
    SERIAL.lock().unwrap_or_else(|e| e.into_inner())
}

/// 1 shard, 1 lane: deterministic faultpoint hit windows.
fn model(seed: u32) -> lfsr_prune::serve::CompiledModel {
    synthetic_lenet300_seeded(0.9, 1, 1, seed)
}

/// Fast-cutting tenant: batch 1, so the drain thread answers a lone
/// request on its next pass.
fn quick_cfg() -> TenantConfig {
    TenantConfig {
        batch: 1,
        max_wait: Some(Duration::from_millis(1)),
        span_sample_every: 16,
        max_queue: 64,
        breaker_backoff: Duration::from_secs(120),
    }
}

/// Parked tenant: batch 8 with no flush deadline, so pushed requests
/// sit in the queue forever — the fixture for 429 and 504 paths.
fn parked_cfg() -> TenantConfig {
    TenantConfig { batch: 8, max_wait: None, max_queue: 2, ..quick_cfg() }
}

fn test_server_cfg() -> ServerConfig {
    ServerConfig {
        accept_threads: 1,
        request_timeout: Duration::from_millis(700),
        shed_grace: Duration::from_millis(50),
        ..ServerConfig::default()
    }
}

fn connect(addr: std::net::SocketAddr) -> TcpStream {
    let s = TcpStream::connect_timeout(&addr, Duration::from_secs(2)).expect("connect");
    s.set_nodelay(true).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    s.set_write_timeout(Some(Duration::from_secs(2))).unwrap();
    s
}

fn render_body(x: &[f32]) -> String {
    let vals: Vec<String> = x.iter().map(|v| format!("{v}")).collect();
    format!("{{\"input\": [{}]}}", vals.join(", "))
}

fn post_raw(model: &str, body: &str, extra_headers: &str) -> String {
    format!(
        "POST /v1/models/{model}:predict HTTP/1.1\r\nhost: t\r\n\
         content-type: application/json\r\ncontent-length: {}\r\n{extra_headers}\r\n{body}",
        body.len()
    )
}

/// Read one full response off the wire: status, body, close flag.
fn read_reply(s: &mut TcpStream) -> std::io::Result<(u16, String, bool)> {
    let mut buf = Vec::new();
    let mut chunk = [0u8; 1024];
    let head_end = loop {
        if let Some(p) = buf.windows(4).position(|w| w == b"\r\n\r\n") {
            break p + 4;
        }
        let n = s.read(&mut chunk)?;
        if n == 0 {
            return Err(std::io::Error::new(
                std::io::ErrorKind::UnexpectedEof,
                "closed mid-response",
            ));
        }
        buf.extend_from_slice(&chunk[..n]);
    };
    let head = String::from_utf8(buf[..head_end].to_vec()).expect("utf-8 head");
    let status: u16 = head
        .split_whitespace()
        .nth(1)
        .and_then(|c| c.parse().ok())
        .unwrap_or_else(|| panic!("bad status line in {head:?}"));
    let mut len = 0usize;
    let mut close = false;
    for line in head.split("\r\n").skip(1) {
        let Some((name, value)) = line.split_once(':') else { continue };
        match name.trim().to_ascii_lowercase().as_str() {
            "content-length" => len = value.trim().parse().expect("content-length"),
            "connection" => close = value.trim().eq_ignore_ascii_case("close"),
            _ => {}
        }
    }
    let mut body = buf[head_end..].to_vec();
    while body.len() < len {
        let n = s.read(&mut chunk)?;
        assert!(n > 0, "closed mid-body");
        body.extend_from_slice(&chunk[..n]);
    }
    Ok((status, String::from_utf8(body).expect("utf-8 body"), close))
}

/// One request/response exchange on a fresh connection.  A failed write
/// is tolerated: a server rejecting early (413/431) may close before the
/// whole request lands, and the response is still readable.
fn exchange(addr: std::net::SocketAddr, raw: &str) -> (u16, String, bool) {
    let mut s = connect(addr);
    let _ = s.write_all(raw.as_bytes());
    read_reply(&mut s).expect("reply")
}

fn input(dim: usize, seed: u64) -> Vec<f32> {
    let mut rng = Pcg32::new(0x177E ^ seed);
    (0..dim).map(|_| rng.next_normal()).collect()
}

#[test]
fn predict_answers_bitwise_and_keep_alive_carries_a_second_request() {
    let _s = serial();
    faultpoint::disarm();
    let m = model(11);
    let dim = m.in_dim();
    let solo = InferenceSession::new(m.clone(), 1);
    let reg = Arc::new(ModelRegistry::new(2));
    reg.insert("lenet", m, quick_cfg()).unwrap();
    let server = HttpServer::start(Arc::clone(&reg), "127.0.0.1:0", test_server_cfg()).unwrap();
    let addr = server.addr();

    let mut conn = connect(addr);
    for req_i in 0..2u64 {
        let x = input(dim, req_i);
        let expected = solo.infer_one(&x);
        conn.write_all(post_raw("lenet", &render_body(&x), "").as_bytes()).unwrap();
        let (status, body, close) = read_reply(&mut conn).expect("reply");
        assert_eq!(status, 200, "{body}");
        assert!(!close, "keep-alive holds between requests");
        let doc = json::parse(&body).expect("answer is json");
        assert_eq!(doc.get("model").and_then(Json::as_str), Some("lenet"));
        let logits: Vec<f32> = doc
            .get("logits")
            .and_then(Json::as_arr)
            .expect("logits array")
            .iter()
            .map(|v| v.as_f64().expect("number") as f32)
            .collect();
        assert_eq!(logits.len(), expected.len());
        for (i, (&got, &want)) in logits.iter().zip(&expected).enumerate() {
            assert_eq!(
                got.to_bits(),
                want.to_bits(),
                "logit {i} of request {req_i} must round-trip the wire bitwise"
            );
        }
    }
    drop(conn);
    server.shutdown();
    let s = reg.stats("lenet").unwrap();
    assert_eq!((s.requests, s.completed), (2, 2), "both wire requests served");
}

#[test]
fn typed_statuses_cover_the_rejection_table_and_service_survives_each() {
    let _s = serial();
    faultpoint::disarm();
    let m = model(13);
    let dim = m.in_dim();
    let reg = Arc::new(ModelRegistry::new(2));
    reg.insert("lenet", m, quick_cfg()).unwrap();
    let server = HttpServer::start(
        Arc::clone(&reg),
        "127.0.0.1:0",
        ServerConfig {
            limits: Limits { max_head_bytes: 1024, max_body_bytes: 32 * 1024 },
            ..test_server_cfg()
        },
    )
    .unwrap();
    let addr = server.addr();

    // Each row: (raw request, expected status).
    let good = post_raw("lenet", &render_body(&input(dim, 0)), "");
    let cases: Vec<(String, u16)> = vec![
        // Bad JSON body.
        (post_raw("lenet", "not json at all", ""), 400),
        // JSON but no "input".
        (post_raw("lenet", "{\"x\": 1}", ""), 400),
        // Non-numeric input element.
        (post_raw("lenet", "{\"input\": [1, \"two\"]}", ""), 400),
        // Wrong input length: the registry's typed BadInput.
        (post_raw("lenet", "{\"input\": [1, 2, 3]}", ""), 400),
        // Bad deadline header.
        (post_raw("lenet", "{\"input\": []}", "x-deadline-ms: soon\r\n"), 400),
        // Unknown model.
        (post_raw("ghost", "{\"input\": [1]}", ""), 404),
        // Wrong method on predict / metrics, unknown route.
        ("GET /v1/models/lenet:predict HTTP/1.1\r\n\r\n".into(), 405),
        ("POST /metrics HTTP/1.1\r\ncontent-length: 0\r\n\r\n".into(), 405),
        ("GET /nope HTTP/1.1\r\n\r\n".into(), 404),
        // Unparseable content-length.
        ("POST /x HTTP/1.1\r\ncontent-length: abc\r\n\r\n".into(), 400),
        // Declared body past the limit — rejected before it is sent.
        ("POST /x HTTP/1.1\r\ncontent-length: 50000\r\n\r\n".into(), 413),
        // Head past the limit (padded past the parser's 4096-byte read
        // chunk so the over-limit check fires before the head completes).
        (format!("GET /x HTTP/1.1\r\nx-pad: {}\r\n\r\n", "a".repeat(8192)), 431),
    ];
    for (raw, want) in &cases {
        let (status, body, _) = exchange(addr, raw);
        assert_eq!(status, *want, "request {raw:?} -> {body}");
        // The error body is json with an "error" key.
        let doc = json::parse(&body).expect("error body is json");
        assert!(doc.get("error").is_some(), "{body}");
        // The server survives hostile input: a good request still lands.
        let (status, body, _) = exchange(addr, &good);
        assert_eq!(status, 200, "service must survive {raw:?}: {body}");
    }

    // A peer that writes half a request and vanishes gets no response
    // and costs nothing.
    let mut s = connect(addr);
    s.write_all(b"POST /v1/models/lenet:predict HTTP/1.1\r\ncontent-le").unwrap();
    drop(s);
    let (status, _, _) = exchange(addr, &good);
    assert_eq!(status, 200, "truncated peer must not wedge the server");
    server.shutdown();
}

#[test]
fn full_queue_returns_429_and_expired_deadline_returns_504() {
    let _s = serial();
    faultpoint::disarm();
    let dim = model(17).in_dim();
    let reg = Arc::new(ModelRegistry::new(2));
    // batch 8 / max_wait None / max_queue 2: nothing is ever cut, so the
    // queue state is fully under the test's control.  Two parked tenants
    // because a parked request never leaves its queue: the 504 fixture
    // would otherwise still hold a slot during the 429 phase.
    reg.insert("parked-a", model(17), parked_cfg()).unwrap();
    reg.insert("parked-b", model(19), parked_cfg()).unwrap();
    let server = HttpServer::start(Arc::clone(&reg), "127.0.0.1:0", test_server_cfg()).unwrap();
    let addr = server.addr();

    // A lone request with a deadline parks in the queue until the
    // deadline passes: 504, attributed to the deadline (not a 503).
    let t0 = Instant::now();
    let (status, body, _) = exchange(
        addr,
        &post_raw("parked-a", &render_body(&input(dim, 0)), "x-deadline-ms: 150\r\n"),
    );
    assert_eq!(status, 504, "{body}");
    assert!(
        t0.elapsed() >= Duration::from_millis(150),
        "the 504 must not fire before the deadline"
    );

    // Fill parked-b's 2-slot queue, then the third concurrent request
    // is refused at admission: 429 with retry-after.
    let fill: Vec<_> = (0..2)
        .map(|i| {
            let raw = post_raw("parked-b", &render_body(&input(dim, i)), "x-deadline-ms: 400\r\n");
            std::thread::spawn(move || exchange(addr, &raw))
        })
        .collect();
    // Let both fillers enqueue (they park server-side for 400 ms); the
    // 504 fixture above still holds its parked-a slot.
    std::thread::sleep(Duration::from_millis(150));
    assert_eq!(reg.pending(), 3, "both fillers (and the 504 fixture) must be queued");
    let (status, body, _) =
        exchange(addr, &post_raw("parked-b", &render_body(&input(dim, 9)), ""));
    assert_eq!(status, 429, "{body}");
    assert!(body.contains("overloaded"), "{body}");
    for h in fill {
        let (status, _, _) = h.join().unwrap();
        assert_eq!(status, 504, "fillers time out on their own deadlines");
    }
    let s = reg.stats("parked-b").unwrap();
    assert_eq!(s.overloaded, 1, "exactly one admission refusal");
    server.shutdown();
}

#[test]
fn injected_socket_reset_drops_one_connection_not_the_server() {
    let _s = serial();
    let m = model(19);
    let dim = m.in_dim();
    let reg = Arc::new(ModelRegistry::new(2));
    reg.insert("lenet", m, quick_cfg()).unwrap();
    let server = HttpServer::start(Arc::clone(&reg), "127.0.0.1:0", test_server_cfg()).unwrap();
    let addr = server.addr();

    let good = post_raw("lenet", &render_body(&input(dim, 0)), "");
    {
        // Window 1..1: exactly the first socket read after arming fails,
        // which is the read serving this doomed connection.
        let plan = FaultPlan::seeded(7).with(points::HTTP_READ, None, FaultAction::Fail, 1, 1);
        let _g = faultpoint::arm(&plan);
        let mut s = connect(addr);
        s.write_all(good.as_bytes()).unwrap();
        let err = read_reply(&mut s).expect_err("injected reset must kill this connection");
        // A close with our request bytes unread surfaces as EOF or RST
        // depending on kernel timing; either way there is no reply.
        assert!(
            matches!(
                err.kind(),
                std::io::ErrorKind::UnexpectedEof
                    | std::io::ErrorKind::ConnectionReset
                    | std::io::ErrorKind::BrokenPipe
            ),
            "silent close, no reply: {err}"
        );
        assert_eq!(faultpoint::hits(points::HTTP_READ), 1, "the failpoint fired once");
    }
    // Plan disarmed: the very next connection serves normally.
    let (status, body, _) = exchange(addr, &good);
    assert_eq!(status, 200, "{body}");
    server.shutdown();
}

#[test]
fn shard_panic_maps_to_503_for_one_tenant_while_neighbors_serve_200() {
    let _s = serial();
    let dim = model(23).in_dim();
    let reg = Arc::new(ModelRegistry::new(2));
    reg.insert("chaos-a", model(23), quick_cfg()).unwrap();
    reg.insert("quiet-b", model(29), quick_cfg()).unwrap();
    let server = HttpServer::start(Arc::clone(&reg), "127.0.0.1:0", test_server_cfg()).unwrap();
    let addr = server.addr();

    // Panic on the first chaos-a shard execution; the 120 s breaker
    // backoff keeps the tenant quarantined for the rest of the test.
    let plan =
        FaultPlan::seeded(7).with(points::SESSION_SHARD, Some("chaos-a"), FaultAction::Panic, 1, 1);
    let _g = faultpoint::arm(&plan);

    // The sacrificial request rides the panicking batch: its answer
    // never arrives, and with a deadline set the handler reports 504.
    let (status, body, _) =
        exchange(addr, &post_raw("chaos-a", &render_body(&input(dim, 0)), "x-deadline-ms: 200\r\n"));
    assert_eq!(status, 504, "{body}");

    // Quarantine is now wire-visible at admission: 503 + retry-after
    // for the faulted tenant, while the neighbor still answers 200.
    let mut s = connect(addr);
    s.write_all(post_raw("chaos-a", &render_body(&input(dim, 1)), "").as_bytes()).unwrap();
    let (status, body, _) = read_reply(&mut s).expect("reply");
    assert_eq!(status, 503, "{body}");
    assert!(body.contains("quarantined"), "{body}");
    let (status, body, _) = exchange(addr, &post_raw("quiet-b", &render_body(&input(dim, 2)), ""));
    assert_eq!(status, 200, "neighbor must keep serving: {body}");

    let text = reg.metrics_text();
    assert!(text.contains("serve_tenant_healthy{model=\"chaos-a\"} 0\n"), "{text}");
    assert!(text.contains("serve_tenant_healthy{model=\"quiet-b\"} 1\n"), "{text}");
    // Shutdown must complete even though chaos-a still holds an
    // uncompletable queued request behind its breaker.
    server.shutdown();
}

#[test]
fn metrics_exposition_over_http_parses_and_counts_requests() {
    let _s = serial();
    faultpoint::disarm();
    let m = model(31);
    let dim = m.in_dim();
    let reg = Arc::new(ModelRegistry::new(2));
    reg.insert("lenet", m, quick_cfg()).unwrap();
    let server = HttpServer::start(Arc::clone(&reg), "127.0.0.1:0", test_server_cfg()).unwrap();
    let addr = server.addr();

    for i in 0..3 {
        let (status, _, _) =
            exchange(addr, &post_raw("lenet", &render_body(&input(dim, i)), ""));
        assert_eq!(status, 200);
    }
    let (_, _, _) = exchange(addr, &post_raw("ghost", "{\"input\": [1]}", ""));

    let (status, body, _) = exchange(addr, "GET /metrics HTTP/1.1\r\n\r\n");
    assert_eq!(status, 200);
    // Every non-comment line is `name{labels} value` with a numeric
    // value — the exposition stays machine-readable under live traffic.
    let mut lines = 0;
    for line in body.lines().filter(|l| !l.is_empty() && !l.starts_with('#')) {
        let (_, value) = line.rsplit_once(' ').unwrap_or_else(|| panic!("bad line {line:?}"));
        value.parse::<f64>().unwrap_or_else(|_| panic!("non-numeric value in {line:?}"));
        lines += 1;
    }
    assert!(lines > 10, "exposition should carry real content:\n{body}");
    assert!(body.contains("http_requests_total{code=\"200\"} 3\n"), "{body}");
    assert!(body.contains("http_requests_total{code=\"404\"} 1\n"), "{body}");
    assert!(body.contains("serve_queue_depth{model=\"lenet\"}"), "{body}");
    assert!(body.contains("alloc_allocations_total"), "{body}");
    assert!(body.contains("http_connections_active"), "{body}");

    let (status, body, _) = exchange(addr, "GET /healthz HTTP/1.1\r\n\r\n");
    assert_eq!(status, 200);
    assert_eq!(body, "ok\n");
    server.shutdown();
}
