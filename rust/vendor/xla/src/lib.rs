//! Offline shim for the `xla` PJRT bindings.
//!
//! The real bindings wrap a PJRT CPU plugin and are not in the offline
//! vendor set.  This crate reproduces the exact API slice that
//! `lfsr_prune::runtime` consumes so the workspace builds and tests
//! everywhere:
//!
//! * [`Literal`] is **fully functional** (host-side construction, reshape,
//!   download, tuples) — the tensor marshalling layer and its tests run
//!   for real against it.
//! * [`PjRtClient::compile`] / [`PjRtLoadedExecutable::execute_b`] return a
//!   descriptive error: executing AOT artifacts needs the real plugin.
//!   Everything artifact-dependent already skips gracefully when
//!   `artifacts/manifest.json` is absent, so tier-1 stays green.
//!
//! Dropping in the real bindings is a one-line Cargo.toml change; no
//! `lfsr_prune` source changes are required.

use std::borrow::Borrow;
use std::fmt;
use std::path::Path;

/// Error type mirroring the bindings' (stringly, Debug-printable).
#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla: {}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what} is unavailable in the offline xla shim; swap \
         `xla = {{ path = \"vendor/xla\" }}` for the real PJRT bindings to \
         run AOT artifacts"
    ))
}

/// Element types (the artifacts only use F32/S32; the rest exist so
/// dtype mismatches stay representable, as in the real bindings).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    Pred,
    S32,
    S64,
    F32,
    F64,
}

mod sealed {
    pub trait Sealed {}
    impl Sealed for f32 {}
    impl Sealed for i32 {}
}

/// Host dtypes a [`Literal`] can hold.
pub trait NativeType: Copy + sealed::Sealed {
    fn element_type() -> ElementType;
    fn into_data(v: Vec<Self>) -> Data;
    fn slice_of(d: &Data) -> Option<&[Self]>;
}

impl NativeType for f32 {
    fn element_type() -> ElementType {
        ElementType::F32
    }
    fn into_data(v: Vec<Self>) -> Data {
        Data::F32(v)
    }
    fn slice_of(d: &Data) -> Option<&[Self]> {
        match d {
            Data::F32(v) => Some(v),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    fn element_type() -> ElementType {
        ElementType::S32
    }
    fn into_data(v: Vec<Self>) -> Data {
        Data::I32(v)
    }
    fn slice_of(d: &Data) -> Option<&[Self]> {
        match d {
            Data::I32(v) => Some(v),
            _ => None,
        }
    }
}

/// Literal payload (public only so `NativeType` can name it).
#[derive(Debug, Clone, PartialEq)]
pub enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Tuple(Vec<Literal>),
}

/// Array shape: dims + element type.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArrayShape {
    dims: Vec<i64>,
    ty: ElementType,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn ty(&self) -> ElementType {
        self.ty
    }
}

/// Host-side literal: a dense array (f32/i32) or a tuple of literals.
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    dims: Vec<i64>,
    data: Data,
}

impl Literal {
    /// Rank-0 literal.
    pub fn scalar<T: NativeType>(v: T) -> Literal {
        Literal {
            dims: Vec::new(),
            data: T::into_data(vec![v]),
        }
    }

    /// Rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(v: &[T]) -> Literal {
        Literal {
            dims: vec![v.len() as i64],
            data: T::into_data(v.to_vec()),
        }
    }

    /// Tuple literal (what executables return with `return_tuple=True`).
    pub fn tuple(elems: Vec<Literal>) -> Literal {
        Literal {
            dims: Vec::new(),
            data: Data::Tuple(elems),
        }
    }

    fn element_count(&self) -> usize {
        match &self.data {
            Data::F32(v) => v.len(),
            Data::I32(v) => v.len(),
            Data::Tuple(_) => 0,
        }
    }

    /// Same data, new dims (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        if matches!(self.data, Data::Tuple(_)) {
            return Err(Error("cannot reshape a tuple literal".into()));
        }
        let n: i64 = dims.iter().product();
        if n as usize != self.element_count() {
            return Err(Error(format!(
                "reshape {:?} -> {dims:?}: element count mismatch",
                self.dims
            )));
        }
        Ok(Literal {
            dims: dims.to_vec(),
            data: self.data.clone(),
        })
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        let ty = match &self.data {
            Data::F32(_) => ElementType::F32,
            Data::I32(_) => ElementType::S32,
            Data::Tuple(_) => return Err(Error("tuple literal has no array shape".into())),
        };
        Ok(ArrayShape {
            dims: self.dims.clone(),
            ty,
        })
    }

    /// Download as a host vector of `T` (dtype must match).
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::slice_of(&self.data)
            .map(<[T]>::to_vec)
            .ok_or_else(|| Error("literal dtype mismatch in to_vec".into()))
    }

    /// Decompose a tuple literal into its elements.
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        match &self.data {
            Data::Tuple(v) => Ok(v.clone()),
            _ => Err(Error("literal is not a tuple".into())),
        }
    }

    /// First element of a dense literal (loss/accuracy scalars).
    pub fn get_first_element<T: NativeType>(&self) -> Result<T> {
        T::slice_of(&self.data)
            .and_then(|s| s.first().copied())
            .ok_or_else(|| Error("empty or mismatched literal in get_first_element".into()))
    }
}

/// Parsed HLO module. The shim cannot parse HLO text, so construction
/// fails with a descriptive error (artifact-gated code never reaches it
/// without `make artifacts`, which documents the real-bindings setup).
#[derive(Debug)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(path: P) -> Result<Self> {
        Err(unavailable(&format!(
            "parsing HLO text ({})",
            path.as_ref().display()
        )))
    }
}

/// An XLA computation wrapping an HLO module.
#[derive(Debug)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// PJRT client handle. Construction succeeds so manifest-less tooling
/// (`repro help`, mask/hw paths) works; only compile/execute are gated.
#[derive(Debug, Clone)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient)
    }

    pub fn platform_name(&self) -> String {
        "cpu (offline xla shim; compile/execute disabled)".to_string()
    }

    pub fn buffer_from_host_literal(
        &self,
        _device: Option<usize>,
        lit: &Literal,
    ) -> Result<PjRtBuffer> {
        Ok(PjRtBuffer { lit: lit.clone() })
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("compiling an XlaComputation"))
    }
}

/// Device buffer (host-backed in the shim).
#[derive(Debug, Clone)]
pub struct PjRtBuffer {
    lit: Literal,
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Ok(self.lit.clone())
    }
}

/// Compiled executable handle.
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    client: PjRtClient,
}

impl PjRtLoadedExecutable {
    pub fn client(&self) -> &PjRtClient {
        &self.client
    }

    pub fn execute_b<B: Borrow<PjRtBuffer>>(&self, _args: &[B]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("executing a PjRtLoadedExecutable"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_scalar_and_vec1() {
        let s = Literal::scalar(2.5f32);
        assert_eq!(s.get_first_element::<f32>().unwrap(), 2.5);
        assert!(s.array_shape().unwrap().dims().is_empty());
        let v = Literal::vec1(&[1i32, 2, 3]);
        assert_eq!(v.to_vec::<i32>().unwrap(), vec![1, 2, 3]);
        assert_eq!(v.array_shape().unwrap().ty(), ElementType::S32);
    }

    #[test]
    fn reshape_checks_count() {
        let v = Literal::vec1(&[0f32; 6]);
        let m = v.reshape(&[2, 3]).unwrap();
        assert_eq!(m.array_shape().unwrap().dims(), &[2, 3]);
        assert!(v.reshape(&[4, 2]).is_err());
    }

    #[test]
    fn tuple_roundtrip() {
        let t = Literal::tuple(vec![Literal::scalar(1f32), Literal::vec1(&[7i32])]);
        let parts = t.to_tuple().unwrap();
        assert_eq!(parts.len(), 2);
        assert!(t.array_shape().is_err());
        assert!(Literal::scalar(0f32).to_tuple().is_err());
    }

    #[test]
    fn execution_paths_report_shim() {
        let client = PjRtClient::cpu().unwrap();
        assert!(client.platform_name().contains("shim"));
        let err = client.compile(&XlaComputation).unwrap_err();
        assert!(err.to_string().contains("offline xla shim"), "{err}");
        assert!(HloModuleProto::from_text_file("x.hlo").is_err());
    }

    #[test]
    fn buffers_roundtrip_host_literals() {
        let client = PjRtClient::cpu().unwrap();
        let lit = Literal::vec1(&[1f32, 2.0]).reshape(&[2, 1]).unwrap();
        let buf = client.buffer_from_host_literal(None, &lit).unwrap();
        assert_eq!(buf.to_literal_sync().unwrap(), lit);
    }
}
