//! Quickstart: the three layers in one file.
//!
//!   1. the LFSR primitive (rust) and the paper's index mapping;
//!   2. an AOT Pallas kernel executed from rust over PJRT, checked against
//!      both a host matmul and the rust LFSR (cross-language contract);
//!   3. a miniature run of the paper's 4-stage pruning pipeline.
//!
//! Run: `make artifacts && cargo run --release --example quickstart`

use lfsr_prune::lfsr::{GaloisLfsr, MsbMap};
use lfsr_prune::mask::prs::{prs_mask, PrsMaskConfig};
use lfsr_prune::pipeline::{run_trial, DataConfig, MaskMethod, PipelineConfig, RegType};
use lfsr_prune::runtime::{Runtime, Tensor};

fn main() -> anyhow::Result<()> {
    // ---- 1. the LFSR primitive ---------------------------------------
    let mut lfsr = GaloisLfsr::new(16, 0xACE1);
    let states: Vec<u32> = (0..8).map(|_| lfsr.next_state()).collect();
    println!("LFSR(16, seed=0xACE1) states: {states:04x?}");
    let mut map = MsbMap::new(GaloisLfsr::new(16, 0xACE1), 784);
    let idx: Vec<usize> = (0..8).map(|_| map.next_index()).collect();
    println!("paper §2.4 index map -> [0,784): {idx:?}");

    // A PRS keep-mask for a 784x300 FC layer at 70% sparsity.
    let cfg = PrsMaskConfig::auto(784, 300, 0xACE1, 0x1D3);
    let mask = prs_mask(784, 300, 0.70, cfg);
    println!(
        "PRS mask 784x300 @ 70%: {} kept synapses, index memory = {} bits (two seeds)",
        mask.nnz(),
        cfg.seed_bits()
    );

    // ---- 2. AOT kernel over PJRT --------------------------------------
    let rt = Runtime::new(Runtime::default_dir())?;
    println!("\nPJRT platform: {}", rt.platform());
    let mm = rt.manifest.kernels["mm_demo"].clone();
    let x: Vec<f32> = (0..16 * 64).map(|i| (i % 7) as f32 * 0.1).collect();
    let w: Vec<f32> = (0..64 * 32).map(|i| (i % 5) as f32 * 0.2 - 0.4).collect();
    let m: Vec<f32> = (0..64 * 32).map(|i| (i % 3 == 0) as u32 as f32).collect();
    let y = rt.execute(
        &mm.file,
        &[
            Tensor::f32(vec![16, 64], x),
            Tensor::f32(vec![64, 32], w),
            Tensor::f32(vec![64, 32], m),
        ],
    )?;
    println!(
        "Pallas masked-matmul artifact: out shape {:?}, out[0][0..4] = {:?}",
        y[0].dims,
        &y[0].as_f32()[..4]
    );

    // Cross-language LFSR contract: the Pallas jump-matrix kernel and the
    // rust Galois LFSR derive the same indices from the same seed.
    let k = rt.manifest.kernels["lfsr_idx"].clone();
    let offsets: Vec<i32> = (1..=1024).collect();
    let outs = rt.execute(
        &k.file,
        &[
            Tensor::i32(vec![8, 128], offsets),
            Tensor::i32(vec![], vec![0x5EED]),
        ],
    )?;
    let mut rust_map = MsbMap::new(
        GaloisLfsr::new(k.fields["n"] as u32, 0x5EED),
        k.fields["domain"] as usize,
    );
    let agree = outs[0]
        .as_i32()
        .iter()
        .all(|&v| v as usize == rust_map.next_index());
    println!("lfsr_idx artifact vs rust LFSR: {}", if agree { "IDENTICAL" } else { "MISMATCH!" });
    assert!(agree);

    // ---- 3. mini pruning pipeline -------------------------------------
    println!("\nmini 4-stage pipeline (LeNet-300-100, 70% PRS sparsity):");
    let cfg = PipelineConfig {
        model: "lenet300".into(),
        data: DataConfig::MnistLike,
        method: MaskMethod::Prs { seed_base: 0xACE1 },
        sparsity: 0.7,
        lam: 2.0,
        reg: RegType::L2,
        dense_steps: 80,
        reg_steps: 50,
        retrain_steps: 50,
        lr_dense: 0.1,
        lr_reg: 0.05,
        lr_retrain: 0.02,
        n_train: 2048,
        n_eval: 512,
        trial_seed: 1,
        eval_limit: Some(256),
        output_layer_factor: 0.8,
    };
    let r = run_trial(&rt, &cfg, None)?;
    println!("  dense      acc {:.1}%", r.dense.accuracy * 100.0);
    println!("  regularized acc {:.1}%", r.after_reg.accuracy * 100.0);
    println!("  pruned     acc {:.1}%  (before retraining)", r.pruned.accuracy * 100.0);
    println!("  retrained  acc {:.1}%", r.retrained.accuracy * 100.0);
    println!(
        "  compression {:.1}x ({} -> {} params)",
        r.compression_rate(),
        r.params_total,
        r.params_nonzero
    );
    Ok(())
}
