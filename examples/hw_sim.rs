//! Hardware-simulator deep dive: run both cycle-level engines on the same
//! PRS-pruned layer, verify they compute the identical matvec, and show
//! where every picojoule goes (paper Fig. 2 datapaths, Tables 4-5 cells).
//!
//! Run: `cargo run --release --example hw_sim [sparsity] [--stream]`

use lfsr_prune::data::rng::Pcg32;
use lfsr_prune::hw::{self, baseline, lfsr_engine, Mode, SparseLayer};
use lfsr_prune::mask::prs::{prs_mask_with_stats, PrsMaskConfig};

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let sparsity: f64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(0.9);
    let stream = args.iter().any(|a| a == "--stream");
    let mode = if stream { Mode::Stream } else { Mode::Ideal };

    // LeNet-300-100 fc1 at paper dims.
    let (rows, cols) = (784usize, 300usize);
    let cfg = PrsMaskConfig::auto(rows, cols, 0xACE1, 0x1D3);
    let (mask, stats) = prs_mask_with_stats(rows, cols, sparsity, cfg);
    let mut rng = Pcg32::new(42);
    let layer = SparseLayer {
        rows,
        cols,
        weights: (0..rows * cols).map(|_| rng.next_normal()).collect(),
        mask: mask.clone(),
        input: (0..rows).map(|_| rng.next_normal()).collect(),
    };
    println!(
        "layer {rows}x{cols} @ {:.0}% sparsity: nnz {}  walk steps {} (collision overhead {:.2}x)",
        sparsity * 100.0,
        mask.nnz(),
        stats.total_steps,
        stats.overhead()
    );

    let ref_out = layer.reference_output();
    println!("\n-- baseline CSC engine (4b and 8b indices) --");
    for bits in [4u32, 8] {
        let r = baseline::run(&layer, bits, 8);
        let ok = r
            .output
            .iter()
            .zip(&ref_out)
            .all(|(a, b)| (a - b).abs() < 1e-3);
        let c = r.counters;
        println!(
            "  {bits}b: cycles {}  macs {}  S-reads {}  I-reads {}  P-reads {}  fillers {}  correct={}",
            c.cycles, c.mac_ops, c.weight_reads, c.index_reads, c.ptr_reads, c.fillers, ok
        );
    }

    println!("\n-- proposed LFSR engine ({mode:?} mode) --");
    let r = lfsr_engine::run(&layer, cfg, mode);
    let ok = r
        .output
        .iter()
        .zip(&ref_out)
        .all(|(a, b)| (a - b).abs() < 1e-3);
    let c = r.counters;
    println!(
        "  cycles {}  macs {}  W-reads {}  I-reads {}  lfsr ticks {}  out-RMW {}  collisions {}  correct={}",
        c.cycles, c.mac_ops, c.weight_reads, c.index_reads, c.lfsr_ticks, c.output_reads, c.collision_cycles, ok
    );

    println!("\n-- system comparison (closed-form, whole LeNet-300-100) --");
    let net = hw::layers::lenet300();
    for bits in [4u32, 8] {
        let cmp = hw::compare(&net, sparsity, bits, mode, 16);
        println!(
            "  {bits}b: baseline {:.1} mW / {:.3} mm²  proposed {:.1} mW / {:.3} mm²  -> save {:.1}% / {:.1}%  mem x{:.2}",
            cmp.baseline.avg_power_mw,
            cmp.baseline.area_mm2,
            cmp.proposed.avg_power_mw,
            cmp.proposed.area_mm2,
            cmp.power_saving_pct(),
            cmp.area_saving_pct(),
            cmp.memory_reduction()
        );
    }
}
