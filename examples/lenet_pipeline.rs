//! End-to-end validation driver (EXPERIMENTS.md §End-to-end): the complete
//! paper pipeline at full experiment scale on LeNet-300-100 —
//! dense train → PRS regularize → prune → retrain — with the loss curve
//! logged per step, followed by the *hardware consequences* of the run:
//! the trained masks are handed to the cycle-level engines and the
//! memory/power/area comparison is reported for this exact model.
//!
//! Run: `cargo run --release --example lenet_pipeline [sparsity]`

use lfsr_prune::hw::{self, Mode};
use lfsr_prune::pipeline::{run_trial, DataConfig, MaskMethod, PipelineConfig, RegType};
use lfsr_prune::runtime::Runtime;
use lfsr_prune::sparse::{baseline_footprint, proposed_footprint};
use lfsr_prune::mask::prs::PrsMaskConfig;

fn main() -> anyhow::Result<()> {
    let sparsity: f64 = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.9);
    let rt = Runtime::new(Runtime::default_dir())?;
    let cfg = PipelineConfig {
        model: "lenet300".into(),
        data: DataConfig::MnistLike,
        method: MaskMethod::Prs { seed_base: 0xACE1 },
        sparsity,
        lam: 2.0,
        reg: RegType::L2,
        dense_steps: 250,
        reg_steps: 150,
        retrain_steps: 150,
        lr_dense: 0.1,
        lr_reg: 0.05,
        lr_retrain: 0.02,
        n_train: 4096,
        n_eval: 1024,
        trial_seed: 7,
        eval_limit: None,
        output_layer_factor: 0.8,
    };
    println!("=== paper pipeline, LeNet-300-100 @ {:.0}% PRS sparsity ===", sparsity * 100.0);
    let t0 = std::time::Instant::now();
    let mut last_phase = String::new();
    let mut cb = |phase: &str, i: usize, loss: f32| {
        if phase != last_phase {
            println!("--- phase: {phase} ---");
            last_phase = phase.to_string();
        }
        if i % 10 == 0 {
            println!("step {i:>4}  loss {loss:.4}");
        }
    };
    let r = run_trial(&rt, &cfg, Some(&mut cb))?;
    println!("\ntotal wall time: {:.1}s", t0.elapsed().as_secs_f64());
    println!("dense      acc {:.2}% (err {:.2}%)", r.dense.accuracy * 100.0, r.dense.error_pct());
    println!("after reg  acc {:.2}%", r.after_reg.accuracy * 100.0);
    println!("pruned     acc {:.2}%", r.pruned.accuracy * 100.0);
    println!("retrained  acc {:.2}% (err {:.2}%)", r.retrained.accuracy * 100.0, r.retrained.error_pct());
    println!(
        "compression {:.1}x ({} -> {} params)\n",
        r.compression_rate(),
        r.params_total,
        r.params_nonzero
    );

    // Hardware consequences of THIS model's masks.
    println!("=== hardware view of the trained masks ===");
    let mut total_b4 = 0u64;
    let mut total_b8 = 0u64;
    let mut total_p = 0u64;
    for (i, m) in r.masks.iter().enumerate() {
        let cfg = PrsMaskConfig::auto(m.rows, m.cols, 0xACE1 + 2 * i as u32 + 1, (0xACE1 + 2 * i as u32 + 2) * 3);
        let b4 = baseline_footprint(m, 4, 8).total();
        let b8 = baseline_footprint(m, 8, 8).total();
        let p = proposed_footprint(m, cfg, 8).total();
        println!(
            "  fc{}: {}x{} nnz {}  baseline 4b {:.1}KB / 8b {:.1}KB  proposed {:.1}KB",
            i + 1,
            m.rows,
            m.cols,
            m.nnz(),
            b4 as f64 / 8192.0,
            b8 as f64 / 8192.0,
            p as f64 / 8192.0
        );
        total_b4 += b4;
        total_b8 += b8;
        total_p += p;
    }
    println!(
        "  total: baseline 4b {:.1}KB / 8b {:.1}KB vs proposed {:.1}KB -> {:.2}x / {:.2}x reduction",
        total_b4 as f64 / 8192.0,
        total_b8 as f64 / 8192.0,
        total_p as f64 / 8192.0,
        total_b4 as f64 / total_p as f64,
        total_b8 as f64 / total_p as f64
    );

    let net = hw::layers::lenet300();
    let c = hw::compare(&net, sparsity, 8, Mode::Ideal, 16);
    println!(
        "  system model @ this sparsity: power saving {:.1}%, area saving {:.1}%",
        c.power_saving_pct(),
        c.area_saving_pct()
    );
    Ok(())
}
