//! Batched-inference server on the first-class serving subsystem
//! (`lfsr_prune::serve`): LFSR seeds are expanded once into a packed
//! compiled model, requests stream in from a client thread, the
//! `Batcher` cuts fixed-size micro-batches (padding the final partial
//! one), and an `InferenceSession` executes them over a worker pool with
//! column-sharded masked GEMM.
//!
//! Unlike the old demo this needs no AOT artifacts: the model is the
//! shared synthetic 90%-sparse LeNet-300-100 (`serve::synthetic_lenet300`,
//! same model `benches/serve.rs` tracks) whose non-zero positions are
//! derived purely from the two per-layer LFSR seeds — the paper's
//! serving premise end to end.
//!
//! Run: `cargo run --release --example infer_server \
//!           [n_requests] [workers] [models] [dump_every_s]`
//!
//! With `models > 1` the server switches to multi-tenant mode: `models`
//! differently-seeded LFSR-pruned LeNets register in a
//! `store::ModelRegistry`, share ONE worker pool, and requests are routed
//! round-robin by model id — each tenant's partial batches are cut by a
//! flush deadline so low-QPS tenants are not starved.  Every other
//! tenant serves the i8 precision tier (per-column-quantized kept
//! values, ~4x smaller value memory) to demonstrate mixed f32/i8
//! tenants on the one shared pool.  Multi-tenant queues are *bounded*
//! (`TenantConfig::max_queue`): a push against a full queue is a typed
//! `RegistryError::Overloaded` rejection, counted and reported rather
//! than retried — the offered load simply exceeds capacity and the
//! server stays at bounded memory (README: "Robustness & overload
//! behavior").
//!
//! With `dump_every_s > 0` the server periodically dumps the full
//! Prometheus-style metrics exposition between `=== metrics ===` /
//! `=== end metrics ===` markers while serving, plus one final dump at
//! the end — CI's metrics smoke step parses exactly this output.  The
//! binary installs `obs::CountingAllocator`, so the dumped
//! `alloc_allocations_total` gauge reports real allocation counts.

use std::sync::mpsc;
use std::time::{Duration, Instant};

use lfsr_prune::data::{synth, SynthSpec};
use lfsr_prune::obs::MetricsRegistry;
use lfsr_prune::serve::{synthetic_lenet300, Batcher, InferenceSession};
use lfsr_prune::store::{ModelRegistry, RegistryError, TenantConfig};

const IN_DIM: usize = 784;
const SPARSITY: f64 = 0.9;
const BATCH: usize = 64;
/// Per-layer span sampling period (see `TenantConfig::span_sample_every`).
const SAMPLE_EVERY: u64 = 16;

#[global_allocator]
static ALLOC: lfsr_prune::obs::CountingAllocator = lfsr_prune::obs::CountingAllocator;

/// Prints the exposition between markers so a log consumer (or CI's
/// smoke step) can slice metric blocks out of the serving output.
fn dump_metrics(text: &str) {
    println!("=== metrics ===");
    print!("{text}");
    println!("=== end metrics ===");
}

fn main() {
    let n_requests: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(4096);
    let workers: usize = std::env::args()
        .nth(2)
        .and_then(|s| s.parse().ok())
        .unwrap_or_else(|| std::thread::available_parallelism().map_or(4, |n| n.get()));
    let models: usize = std::env::args()
        .nth(3)
        .and_then(|s| s.parse().ok())
        .unwrap_or(1);
    let dump_every: f64 = std::env::args()
        .nth(4)
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.0);
    if models > 1 {
        return serve_multi_model(n_requests, workers, models, dump_every);
    }

    // Compile: expand each layer's two LFSR seeds into the packed
    // serving layout (jump-table lanes parallelise the walk replay).
    let t0 = Instant::now();
    let model = synthetic_lenet300(SPARSITY, 4 * workers, workers);
    println!(
        "compiled 3 layers in {:.1} ms: {} kept weights ({:.0}% sparse), seeds are the only index state",
        t0.elapsed().as_secs_f64() * 1e3,
        model.nnz(),
        SPARSITY * 100.0
    );
    println!("{}", model.describe());
    let mut session = InferenceSession::new(model, workers);
    println!("serving with {} worker thread(s), batch size {BATCH}", session.workers());

    // Single-tenant mode has no ModelRegistry, so it assembles its own
    // exposition registry from the session + batcher metric bundles.
    let metrics = MetricsRegistry::new();
    let alloc_gauge = metrics.gauge("alloc_allocations_total", lfsr_prune::obs::labels(&[]));
    session.enable_metrics(SAMPLE_EVERY).register_into(&metrics, "lenet300");

    // Client thread: streams requests as fast as the server consumes.
    // Each request carries its send timestamp so channel wait counts
    // toward the reported latency.
    let (tx, rx) = mpsc::channel::<(u64, Vec<f32>, Instant)>();
    let feed = synth::generate(&SynthSpec::mnist_like(17), n_requests.max(1));
    let producer = std::thread::spawn(move || {
        let len = feed.example_len();
        for i in 0..n_requests {
            let x = feed.x[i * len..(i + 1) * len].to_vec();
            if tx.send((i as u64, x, Instant::now())).is_err() {
                return;
            }
        }
    });

    // Server loop: drain queue -> cut batches -> answer.  The logits and
    // classes buffers live outside the loop so the steady-state cut ->
    // classify -> complete cycle is allocation-free (arena inference +
    // recycled batcher buffers).
    let mut batcher = Batcher::new(BATCH, IN_DIM);
    batcher.metrics().register_into(&metrics, "lenet300");
    let (mut logits, mut classes) = (Vec::new(), Vec::new());
    let mut answered = 0usize;
    let mut disconnected = false;
    let mut last_dump = Instant::now();
    while answered < n_requests {
        if dump_every > 0.0 && last_dump.elapsed().as_secs_f64() >= dump_every {
            alloc_gauge.set(lfsr_prune::obs::total_allocations() as i64);
            dump_metrics(&metrics.render_text());
            last_dump = Instant::now();
        }
        while let Ok((id, x, sent_at)) = rx.try_recv() {
            // Single-tenant mode leaves the queue unbounded (no
            // `set_max_queue`), so the only possible refusal is a
            // malformed request — which the producer never sends.
            batcher.push_at(id, x, sent_at).expect("well-formed request");
        }
        disconnected = disconnected || producer.is_finished();
        // Cut full batches while the queue is deep; flush partials only
        // once the producer is done (no more arrivals to wait for).
        let flush = disconnected && batcher.pending() > 0;
        match batcher.next_batch(flush) {
            None => std::thread::yield_now(),
            Some(mb) => {
                session.classify_batch_into(&mb.x, mb.batch, &mut logits, &mut classes);
                for (row, &id) in mb.ids.iter().enumerate() {
                    if id % 512 == 0 {
                        println!("  req {id:>5} -> class {}", classes[row]);
                    }
                }
                answered += mb.real;
                batcher.complete(mb);
            }
        }
    }
    producer.join().expect("producer thread");

    let s = batcher.stats();
    println!(
        "\nserved {} of {} pushed requests in {:.2}s -> {:.0} req/s over {} batches \
         ({} padded rows)",
        s.completed,
        s.requests,
        s.wall_s,
        s.throughput_rps(),
        s.batches,
        s.padded
    );
    if let Some(lat) = s.latency {
        println!(
            "latency (send -> answer): median {:.2} ms  mean {:.2} ms  p95 {:.2} ms  p99 {:.2} ms",
            lat.median * 1e3,
            lat.mean * 1e3,
            lat.p95 * 1e3,
            lat.p99 * 1e3
        );
    }
    if dump_every > 0.0 {
        alloc_gauge.set(lfsr_prune::obs::total_allocations() as i64);
        dump_metrics(&metrics.render_text());
    }
}

/// Multi-tenant mode: N differently-seeded models — odd-indexed tenants
/// quantized to the i8 tier — one shared pool, requests routed by model
/// id through the registry.
fn serve_multi_model(n_requests: usize, workers: usize, models: usize, dump_every: f64) {
    use lfsr_prune::sparse::Precision;
    let reg = ModelRegistry::new(workers);
    let cfg = TenantConfig {
        batch: BATCH,
        max_wait: Some(Duration::from_millis(5)),
        span_sample_every: SAMPLE_EVERY,
        // Bounded admission: 4 micro-batches of headroom per tenant;
        // past that, pushes are rejected (counted below), not queued.
        max_queue: 4 * BATCH,
        ..TenantConfig::default()
    };
    let t0 = Instant::now();
    let ids: Vec<String> = (0..models)
        .map(|m| {
            let tier = if m % 2 == 1 { Precision::I8 } else { Precision::F32 };
            let id = format!("lenet300-s{m}-{tier}");
            let model = lfsr_prune::serve::synthetic_lenet300_seeded(
                SPARSITY,
                4 * workers.max(1),
                workers.max(1),
                11 + 40 * m as u32,
            );
            // Compilation already produces f32 — only the i8 tenants pay
            // a conversion.
            let model = match tier {
                Precision::I8 => model.to_precision(tier),
                Precision::F32 => model,
            };
            reg.insert(&id, model, cfg).expect("unique model id");
            id
        })
        .collect();
    println!(
        "registered {models} models (seed bases {:?}, mixed f32/i8 tiers) in {:.1} ms on {} \
         shared worker thread(s)",
        (0..models).map(|m| 11 + 40 * m).collect::<Vec<_>>(),
        t0.elapsed().as_secs_f64() * 1e3,
        reg.workers()
    );

    // Client thread: streams requests round-robin across tenants.
    let (tx, rx) = mpsc::channel::<(usize, u64, Vec<f32>)>();
    let feed = synth::generate(&SynthSpec::mnist_like(17), n_requests.max(1));
    let producer = std::thread::spawn(move || {
        let len = feed.example_len();
        for i in 0..n_requests {
            let x = feed.x[i * len..(i + 1) * len].to_vec();
            if tx.send((i % models, i as u64, x)).is_err() {
                return;
            }
        }
    });

    // Every offered request is either answered or rejected at admission
    // (typed backpressure on a full bounded queue) — nothing is lost
    // silently, and the loop runs until the ledger balances.
    let mut answered = 0usize;
    let mut rejected = 0usize;
    let mut last_dump = Instant::now();
    while answered + rejected < n_requests {
        if dump_every > 0.0 && last_dump.elapsed().as_secs_f64() >= dump_every {
            dump_metrics(&reg.metrics_text());
            last_dump = Instant::now();
        }
        while let Ok((m, id, x)) = rx.try_recv() {
            match reg.push(&ids[m], id, x) {
                Ok(()) => {}
                Err(RegistryError::Overloaded { .. }) => rejected += 1,
                Err(e) => panic!("routed push: {e}"),
            }
        }
        let flush = producer.is_finished() && reg.pending() > 0;
        let batch = reg.drain(flush);
        if batch.is_empty() {
            std::thread::yield_now();
        }
        answered += batch.len();
    }
    producer.join().expect("producer thread");

    println!(
        "\nper-tenant stats ({answered} answered + {rejected} rejected at admission = \
         {n_requests} offered):"
    );
    for info in reg.list() {
        let s = &info.stats;
        let tier = info.precision.map_or("mixed".to_string(), |p| p.to_string());
        println!(
            "  {}: {} done of {} pushed / {} batches -> {:.0} req/s ({}, {} padded rows, \
             nnz {}, {} values) [over {} shed {} failed {} {}]",
            info.id,
            s.completed,
            s.requests,
            s.batches,
            s.throughput_rps(),
            s.latency_cell(),
            s.padded,
            info.nnz,
            tier,
            s.overloaded,
            s.shed,
            s.failed,
            if info.healthy { "healthy" } else { "quarantined" },
        );
    }
    if dump_every > 0.0 {
        dump_metrics(&reg.metrics_text());
    }
}
