//! Batched-inference serving demo: the L3 coordinator accepting requests,
//! batching them to the compiled batch size, executing the pruned model's
//! forward artifact over PJRT, and reporting latency/throughput.
//!
//! Requests are produced by a client thread at a configurable rate; the
//! server thread drains a queue, pads the final partial batch, and
//! answers with argmax labels (vLLM-router-style shape, single worker).
//!
//! Run: `cargo run --release --example infer_server [n_requests]`

use std::collections::VecDeque;
use std::sync::mpsc;
use std::time::Instant;

use lfsr_prune::data::{synth, SynthSpec};
use lfsr_prune::mask::prs::{prs_mask, PrsMaskConfig};
use lfsr_prune::runtime::{ModelRunner, Runtime, StepScalars, Tensor};

struct Request {
    id: usize,
    x: Vec<f32>,
    sent_at: Instant,
}

fn main() -> anyhow::Result<()> {
    let n_requests: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(512);
    let rt = Runtime::new(Runtime::default_dir())?;
    let runner = ModelRunner::new(&rt, "lenet300")?;
    let batch = runner.man.batch;

    // Prepare a pruned model: brief dense training, then PRS masks.
    let data = synth::generate(&SynthSpec::mnist_like(3), 1024);
    let mut params = runner.init_params(9);
    let dense = runner.dense_masks();
    let mut batcher = lfsr_prune::data::Batcher::new(&data, batch, 5);
    for _ in 0..60 {
        let b = batcher.next_batch();
        params = runner
            .train_step(&params, &dense, &b, StepScalars::dense(0.1))?
            .0;
    }
    let midx = runner.maskable_indices();
    let masks: Vec<Tensor> = midx
        .iter()
        .enumerate()
        .map(|(i, &pi)| {
            let s = runner.man.params[pi].shape.clone();
            let m = prs_mask(s[0], s[1], 0.9, PrsMaskConfig::auto(s[0], s[1], 11 + i as u32, 31 + i as u32));
            Tensor::f32(s, m.to_f32())
        })
        .collect();
    // Project weights onto the masks (prune) with one hard step.
    let b = batcher.next_batch();
    params = runner
        .train_step(&params, &masks, &b, StepScalars::retrain(0.0))?
        .0;
    println!("serving a 90%-sparse LeNet-300-100, batch size {batch}");

    // Client thread: generates requests as fast as the server consumes.
    let (tx, rx) = mpsc::channel::<Request>();
    let feed = synth::generate(&SynthSpec::mnist_like(17), n_requests);
    std::thread::spawn(move || {
        let len = feed.example_len();
        for i in 0..n_requests {
            let _ = tx.send(Request {
                id: i,
                x: feed.x[i * len..(i + 1) * len].to_vec(),
                sent_at: Instant::now(),
            });
        }
    });

    // Server loop: drain into batches, execute, record latency.
    let mut queue: VecDeque<Request> = VecDeque::new();
    let mut latencies_ms: Vec<f64> = Vec::with_capacity(n_requests);
    let mut answered = 0usize;
    let t0 = Instant::now();
    while answered < n_requests {
        while let Ok(r) = rx.try_recv() {
            queue.push_back(r);
        }
        if queue.is_empty() {
            std::thread::yield_now();
            continue;
        }
        let take = queue.len().min(batch);
        let reqs: Vec<Request> = queue.drain(..take).collect();
        // Pad to the compiled batch with the first request's payload.
        let mut x = Vec::with_capacity(batch * 784);
        for r in &reqs {
            x.extend_from_slice(&r.x);
        }
        for _ in take..batch {
            x.extend_from_slice(&reqs[0].x);
        }
        let logits = runner.forward(&params, &masks, x)?;
        let l = logits.as_f32();
        for (bi, r) in reqs.iter().enumerate() {
            let row = &l[bi * 10..(bi + 1) * 10];
            let label = row
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            let ms = r.sent_at.elapsed().as_secs_f64() * 1e3;
            latencies_ms.push(ms);
            if r.id % 128 == 0 {
                println!("  req {:>4} -> class {label}  latency {ms:.2} ms", r.id);
            }
            answered += 1;
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    latencies_ms.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let p = |q: f64| latencies_ms[((latencies_ms.len() - 1) as f64 * q) as usize];
    println!(
        "\nserved {n_requests} requests in {wall:.2}s -> {:.0} req/s; latency p50 {:.2} ms  p95 {:.2} ms  p99 {:.2} ms",
        n_requests as f64 / wall,
        p(0.5),
        p(0.95),
        p(0.99)
    );
    Ok(())
}
