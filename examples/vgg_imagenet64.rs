//! The paper's heaviest workload: width-scaled modified VGG-16 on the
//! synthetic down-sampled-ImageNet stand-in (64x64, 1000 classes).
//! Short by default (CPU steps are ~0.8 s); pass a step budget to go
//! longer. Records dense vs pruned accuracy and the full-size hw view.
//!
//! Run: `cargo run --release --example vgg_imagenet64 [dense_steps]`

use lfsr_prune::hw::{self, Mode};
use lfsr_prune::pipeline::{run_trial, DataConfig, MaskMethod, PipelineConfig, RegType};
use lfsr_prune::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let dense_steps: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(40);
    let rt = Runtime::new(Runtime::default_dir())?;
    let cfg = PipelineConfig {
        model: "vgg16".into(),
        data: DataConfig::ImageNet64 { classes: 1000 },
        method: MaskMethod::Prs { seed_base: 0xACE1 },
        sparsity: 0.9,
        lam: 2.0,
        reg: RegType::L2,
        dense_steps,
        reg_steps: dense_steps / 2,
        retrain_steps: dense_steps / 2,
        lr_dense: 0.05,
        lr_reg: 0.02,
        lr_retrain: 0.01,
        n_train: 1024,
        n_eval: 256,
        trial_seed: 3,
        eval_limit: Some(128),
        output_layer_factor: 0.8,
    };
    println!(
        "modified VGG-16 (width-scaled, {} steps dense) @ 90% PRS sparsity on ImageNet64-like",
        dense_steps
    );
    let t0 = std::time::Instant::now();
    let mut cb = |phase: &str, i: usize, loss: f32| {
        if i % 5 == 0 {
            println!("  [{phase} {i:>3}] loss {loss:.4}");
        }
    };
    let r = run_trial(&rt, &cfg, Some(&mut cb))?;
    println!("wall {:.0}s", t0.elapsed().as_secs_f64());
    println!(
        "dense err {:.1}%  pruned err {:.1}%  retrained err {:.1}%  compression {:.1}x",
        r.dense.error_pct(),
        r.pruned.error_pct(),
        r.retrained.error_pct(),
        r.compression_rate()
    );

    // Hardware story at the paper's FULL VGG dims (independent of the
    // width scaling used for CPU training).
    let net = hw::layers::vgg16_modified();
    for (sp, bits) in [(0.95, 4u32), (0.95, 8), (0.4, 8)] {
        let c = hw::compare(&net, sp, bits, Mode::Ideal, 256);
        println!(
            "full-size VGG-16 @ {:.0}%/{bits}b: power saving {:.1}%, area saving {:.1}%, memory x{:.2}",
            sp * 100.0,
            c.power_saving_pct(),
            c.area_saving_pct(),
            c.memory_reduction()
        );
    }
    Ok(())
}
