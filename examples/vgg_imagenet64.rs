//! The paper's heaviest workload: width-scaled modified VGG-16 on the
//! synthetic down-sampled-ImageNet stand-in (64x64, 1000 classes).
//! Short by default (CPU steps are ~0.8 s); pass a step budget to go
//! longer. Records dense vs pruned accuracy and the full-size hw view.
//!
//! Run: `cargo run --release --example vgg_imagenet64 [dense_steps]`
//!
//! **Serve mode** (no AOT artifacts needed): compile the synthetic
//! modified VGG-16 — 13 dense 3×3 convs + 4 max-pools + the PRS-pruned
//! 8192-2048-2048-1000 classifier — and serve batched traffic through
//! the registry over one worker pool:
//!
//! `cargo run --release --example vgg_imagenet64 serve [requests] [workers] [input_hw] [ch_div]`
//!
//! `input_hw`/`ch_div` (default 64/1 = paper size) scale the model for
//! quick smoke runs, e.g. `serve 512 4 32 4`.

use lfsr_prune::hw::{self, Mode};
use lfsr_prune::pipeline::{run_trial, DataConfig, MaskMethod, PipelineConfig, RegType};
use lfsr_prune::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    if std::env::args().nth(1).as_deref() == Some("serve") {
        return serve_mode();
    }
    let dense_steps: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(40);
    let rt = Runtime::new(Runtime::default_dir())?;
    let cfg = PipelineConfig {
        model: "vgg16".into(),
        data: DataConfig::ImageNet64 { classes: 1000 },
        method: MaskMethod::Prs { seed_base: 0xACE1 },
        sparsity: 0.9,
        lam: 2.0,
        reg: RegType::L2,
        dense_steps,
        reg_steps: dense_steps / 2,
        retrain_steps: dense_steps / 2,
        lr_dense: 0.05,
        lr_reg: 0.02,
        lr_retrain: 0.01,
        n_train: 1024,
        n_eval: 256,
        trial_seed: 3,
        eval_limit: Some(128),
        output_layer_factor: 0.8,
    };
    println!(
        "modified VGG-16 (width-scaled, {} steps dense) @ 90% PRS sparsity on ImageNet64-like",
        dense_steps
    );
    let t0 = std::time::Instant::now();
    let mut cb = |phase: &str, i: usize, loss: f32| {
        if i % 5 == 0 {
            println!("  [{phase} {i:>3}] loss {loss:.4}");
        }
    };
    let r = run_trial(&rt, &cfg, Some(&mut cb))?;
    println!("wall {:.0}s", t0.elapsed().as_secs_f64());
    println!(
        "dense err {:.1}%  pruned err {:.1}%  retrained err {:.1}%  compression {:.1}x",
        r.dense.error_pct(),
        r.pruned.error_pct(),
        r.retrained.error_pct(),
        r.compression_rate()
    );

    // Hardware story at the paper's FULL VGG dims (independent of the
    // width scaling used for CPU training).
    let net = hw::layers::vgg16_modified();
    for (sp, bits) in [(0.95, 4u32), (0.95, 8), (0.4, 8)] {
        let c = hw::compare(&net, sp, bits, Mode::Ideal, 256);
        println!(
            "full-size VGG-16 @ {:.0}%/{bits}b: power saving {:.1}%, area saving {:.1}%, memory x{:.2}",
            sp * 100.0,
            c.power_saving_pct(),
            c.area_saving_pct(),
            c.memory_reduction()
        );
    }
    Ok(())
}

/// Serve the compiled VGG-16 end to end: compile from seeds (conv stack
/// dense, classifier PRS-derived), register in a `ModelRegistry` on one
/// shared pool, push synthetic 64×64×3 requests, drain, report.
fn serve_mode() -> anyhow::Result<()> {
    use lfsr_prune::data::rng::Pcg32;
    use lfsr_prune::serve::synthetic_vgg16_scaled;
    use lfsr_prune::store::{ModelRegistry, TenantConfig};
    use std::time::{Duration, Instant};

    let arg = |n: usize, default: usize| {
        std::env::args().nth(n).and_then(|s| s.parse().ok()).unwrap_or(default)
    };
    let requests = arg(2, 256);
    let workers = arg(3, std::thread::available_parallelism().map_or(4, |n| n.get()));
    let input_hw = arg(4, 64);
    let ch_div = arg(5, 1);

    let t0 = Instant::now();
    let model = synthetic_vgg16_scaled(input_hw, ch_div, 0.9, 4 * workers.max(1), workers.max(1));
    let in_dim = model.in_dim();
    let counts = model.layer_kind_counts();
    println!(
        "compiled modified VGG-16 ({input_hw}x{input_hw}x3, ch/{ch_div}) in {:.0} ms: {} conv + \
         {} pool + {} fc layers, {} kept weights",
        t0.elapsed().as_secs_f64() * 1e3,
        counts.conv,
        counts.pool,
        counts.fc,
        model.nnz()
    );
    println!("{}", model.describe());

    let reg = ModelRegistry::new(workers);
    reg.insert(
        "vgg16",
        model,
        TenantConfig {
            batch: 16,
            max_wait: Some(Duration::from_millis(10)),
            span_sample_every: 16,
            ..TenantConfig::default()
        },
    )
    .expect("fresh registry");
    let mut rng = Pcg32::new(64);
    let t1 = Instant::now();
    let mut answered = 0usize;
    let mut pushed = 0usize;
    while answered < requests {
        // Feed in bursts so the batcher always has a full cut available.
        while pushed < requests && pushed < answered + 64 {
            let x: Vec<f32> = (0..in_dim).map(|_| rng.next_f32()).collect();
            reg.push("vgg16", pushed as u64, x).expect("routed push");
            pushed += 1;
        }
        answered += reg.drain(pushed == requests).len();
    }
    let wall = t1.elapsed().as_secs_f64();
    for info in reg.list() {
        let s = &info.stats;
        println!(
            "served {} requests in {:.2}s -> {:.1} req/s over {} batches ({} padded rows, {})",
            s.requests,
            wall,
            requests as f64 / wall,
            s.batches,
            s.padded,
            s.latency_cell(),
        );
    }
    Ok(())
}
